//! `shard` — the multi-process fleet supervisor: one front listener,
//! N child server *processes* on loopback ports, jobs routed by panel
//! hash, crashed shards restarted with backoff.
//!
//! # Why processes, not threads
//!
//! The single server already parallelizes across worker threads; what
//! it cannot do is survive an engine crash (a panicking fit takes the
//! process down, and every queued job with it) or outgrow one address
//! space on thousand-dimensional panels. The supervisor lifts both
//! limits with the cheapest possible mechanism: each shard is this same
//! binary running `serve` on `127.0.0.1:0`, spoken to over the existing
//! JSON-lines protocol — the client half of which is already a library
//! ([`protocol`]'s request builders and frame grammar). No new wire
//! format, no shared memory, and a shard that dies loses only its own
//! in-flight jobs.
//!
//! # Routing and relay
//!
//! Jobs hash their panel (inline bytes or CSV path) and route to
//! `hash % N`, so byte-identical repeat traffic always lands on the
//! same shard and its result cache — the panel-hash LRU (and its disk
//! segment under `--cache-dir/shard-K`) stays as effective as in the
//! single-process tier. A dead preferred shard fails over to the next
//! live one. Every frame a shard emits is relayed to the client
//! **verbatim** — the supervisor never re-renders payloads, so results
//! through the fleet are byte-identical to results from a solo server.
//! One job class does not relay: `watch` subscriptions are rejected at
//! the front (their follow-up `frame`/`end` traffic needs the
//! in-process watch registry a relay tier does not host).
//!
//! # Supervision
//!
//! A monitor thread per slot polls the child; on an unexpected exit it
//! books a restart, fails the jobs in flight on that shard's relay
//! links (each gets a terminal `error` frame — clients never hang), and
//! respawns with exponential backoff (100 ms doubling to 2 s). The
//! fleet-level `metrics` frame aggregates every live shard:
//! `shards_live`, `shard_restarts`, summed job counters, and a
//! `per_shard` array with each shard's queue depth.
//!
//! # Fleet observability
//!
//! The front answers `trace` lookups by fanning the query out to every
//! live shard — trace ids are minted inside the shard that ran the job,
//! so at most one child can know a given id — and relays the matching
//! body verbatim. The Prometheus exposition is *re-rendered* rather
//! than relayed: every child's `metrics` frame carries sparse latency
//! histogram snapshots (see [`crate::obs::hist`]), which the front
//! rebuilds with [`Snapshot::from_parts`](hist::Snapshot::from_parts)
//! and sums bucket-wise — bucketing is deterministic across processes,
//! so quantiles of the merged fleet distribution are exact at bucket
//! resolution. Counters sum across children under the same metric names
//! the solo tier exposes, and the fleet adds its own gauges:
//! `alingam_shards`, `alingam_shards_live` and
//! `alingam_shard_restarts_total`. Shard children inherit the front's
//! `--log-level`/`--log-json` settings; their stderr (where log records
//! go) is currently discarded, so per-shard records are only visible
//! when connecting to a shard directly.

use super::protocol::{self, Json};
use super::worker::Sink;
use super::{Backend, ServeConfig};
use crate::obs::{hist, log, PromText};
use crate::serve::cache::Fnv128;
use crate::util::{Error, Result};
use std::collections::{HashMap, HashSet};
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

/// How often a monitor polls its child for exit.
const MONITOR_POLL: Duration = Duration::from_millis(100);
/// Restart backoff: first delay, and the cap it doubles toward.
const BACKOFF_START: Duration = Duration::from_millis(100);
const BACKOFF_CAP: Duration = Duration::from_secs(2);
/// Timeout for one-shot status/metrics/cancel queries to a shard.
const QUERY_TIMEOUT: Duration = Duration::from_secs(5);

/// One shard slot: the live child (if any) and where it listens.
#[derive(Default)]
struct Slot {
    addr: Option<SocketAddr>,
    pid: Option<u32>,
    child: Option<Child>,
}

/// A relay connection from one front client to one shard: jobs written
/// on `writer`, every response line pumped back verbatim by a reader
/// thread, `pending` tracking job ids that have not reached a terminal
/// frame (so a shard crash can fail exactly those).
#[derive(Clone)]
struct Link {
    writer: Arc<Mutex<TcpStream>>,
    pending: Arc<Mutex<HashSet<String>>>,
    dead: Arc<AtomicBool>,
}

/// Fleet state shared by the front accept loops, the relay readers and
/// the monitors — the multi-process implementation of [`Backend`].
pub(crate) struct Fleet {
    slots: Vec<Mutex<Slot>>,
    restarts: AtomicU64,
    shutdown: AtomicBool,
    stop_flag: Mutex<bool>,
    stop_cv: Condvar,
    conns: Mutex<Vec<(u64, TcpStream)>>,
    next_client: AtomicU64,
    started: Instant,
    /// Unix millis at front start (the `alingam_start_time_seconds`
    /// gauge; the monotonic `started` drives uptime).
    start_unix_ms: u64,
    /// Live relay links, keyed by (front client, shard index).
    links: Mutex<HashMap<(u64, usize), Link>>,
    exe: PathBuf,
    /// Serve settings forwarded to every child verbatim.
    child_args: Vec<String>,
    cache_dir: Option<PathBuf>,
}

impl Fleet {
    fn slot_addr(&self, k: usize) -> Option<SocketAddr> {
        self.slots[k].lock().expect("shard slot").addr
    }

    /// Get (or rebuild) the relay link from `client` to shard `k`.
    fn link_for(&self, client: u64, k: usize, addr: SocketAddr, sink: &Sink) -> Option<Link> {
        let mut links = self.links.lock().expect("shard links");
        if let Some(link) = links.get(&(client, k)) {
            if !link.dead.load(Ordering::SeqCst) {
                return Some(link.clone());
            }
            links.remove(&(client, k));
        }
        let stream = TcpStream::connect_timeout(&addr, QUERY_TIMEOUT).ok()?;
        let _ = stream.set_write_timeout(Some(Duration::from_secs(30)));
        let reader = stream.try_clone().ok()?;
        let link = Link {
            writer: Arc::new(Mutex::new(stream)),
            pending: Arc::new(Mutex::new(HashSet::new())),
            dead: Arc::new(AtomicBool::new(false)),
        };
        let relay = link.clone();
        let relay_sink = sink.clone();
        let _ = thread::Builder::new().name(format!("shard-relay-{k}")).spawn(move || {
            relay_loop(reader, relay, relay_sink);
        });
        links.insert((client, k), link.clone());
        Some(link)
    }
}

/// Pump one shard connection back to the front client, verbatim. On
/// EOF (shard died or closed), fail every job still pending on this
/// link with a terminal `error` frame so no client waits forever.
fn relay_loop(reader: TcpStream, link: Link, sink: Sink) {
    for line in BufReader::new(reader).lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.is_empty() {
            continue;
        }
        // relayed verbatim: payload bytes through the fleet are the
        // payload bytes the shard produced
        sink(&line);
        if let Some(id) = terminal_id(&line) {
            link.pending.lock().expect("link pending").remove(&id);
        }
    }
    link.dead.store(true, Ordering::SeqCst);
    let orphans: Vec<String> =
        link.pending.lock().expect("link pending").drain().collect();
    for id in orphans {
        sink(&protocol::frame_error(
            Some(&id),
            "shard connection lost before the job finished",
        ));
    }
}

/// If `line` is a terminal frame (`result`/`error`/`canceled`), its id.
fn terminal_id(line: &str) -> Option<String> {
    let j = protocol::parse_json(line).ok()?;
    match j.get("event").and_then(Json::as_str) {
        Some("result" | "error" | "canceled") => {
            Some(j.get("id").and_then(Json::as_str).unwrap_or("").to_string())
        }
        _ => None,
    }
}

/// Panel-affinity hash: byte-identical panels (or identical CSV paths)
/// always route to the same shard, keeping its result cache hot.
fn route_hash(spec: &protocol::JobSpec) -> u64 {
    let mut h = Fnv128::new();
    match &spec.panel {
        protocol::PanelSource::Inline(m) => {
            h.write_u64(m.rows() as u64);
            h.write_u64(m.cols() as u64);
            for &v in m.as_slice() {
                h.write_f64_bits(v);
            }
        }
        protocol::PanelSource::Csv(path) => h.write_str(path),
    }
    h.finish() as u64
}

/// One-shot control exchange with a shard: connect, send one frame,
/// read one raw reply line. Used directly when the front re-wraps the
/// reply textually (trace relay) instead of re-parsing it.
fn one_shot_raw(addr: SocketAddr, line: &str) -> Option<String> {
    let mut stream = TcpStream::connect_timeout(&addr, QUERY_TIMEOUT).ok()?;
    let _ = stream.set_read_timeout(Some(QUERY_TIMEOUT));
    let _ = stream.set_write_timeout(Some(QUERY_TIMEOUT));
    stream.write_all(line.as_bytes()).ok()?;
    stream.write_all(b"\n").ok()?;
    let mut reader = BufReader::new(stream);
    let mut reply = String::new();
    reader.read_line(&mut reply).ok()?;
    Some(reply.trim_end().to_string())
}

/// [`one_shot_raw`], parsed.
fn one_shot(addr: SocketAddr, line: &str) -> Option<Json> {
    protocol::parse_json(&one_shot_raw(addr, line)?).ok()
}

fn get_u64(j: &Json, path: &[&str]) -> u64 {
    let mut cur = j;
    for key in path {
        match cur.get(key) {
            Some(v) => cur = v,
            None => return 0,
        }
    }
    cur.as_u64().unwrap_or(0)
}

/// Rebuild a latency-histogram snapshot from the sparse JSON object a
/// child's `metrics` frame carries under `"obs"`. Malformed or missing
/// fields degrade to an empty snapshot — the frame came over a socket.
fn snapshot_from_json(j: &Json) -> hist::Snapshot {
    let pairs: Vec<(usize, u64)> = j
        .get("buckets")
        .and_then(Json::as_arr)
        .map(|arr| {
            arr.iter()
                .filter_map(|p| {
                    let p = p.as_arr()?;
                    Some((p.first()?.as_usize()?, p.get(1)?.as_u64()?))
                })
                .collect()
        })
        .unwrap_or_default();
    hist::Snapshot::from_parts(
        get_u64(j, &["count"]),
        get_u64(j, &["sum_us"]),
        get_u64(j, &["max_us"]),
        &pairs,
    )
}

impl Backend for Fleet {
    fn status_frame(&self, id: Option<&str>) -> String {
        let mut queue_depth = 0u64;
        let mut in_flight = 0u64;
        let mut workers = 0u64;
        let mut live = 0usize;
        for k in 0..self.slots.len() {
            let Some(addr) = self.slot_addr(k) else { continue };
            let Some(j) = one_shot(addr, &protocol::control_request("status")) else { continue };
            live += 1;
            queue_depth += get_u64(&j, &["queue_depth"]);
            in_flight += get_u64(&j, &["in_flight"]);
            workers += get_u64(&j, &["workers"]);
        }
        let body = format!(
            "\"event\":\"status\",\"queue_depth\":{queue_depth},\"in_flight\":{in_flight},\
             \"workers\":{workers},\"uptime_ms\":{},\"accepting\":{},\"shards\":{},\
             \"shards_live\":{live}",
            self.started.elapsed().as_millis(),
            !self.shutdown.load(Ordering::SeqCst),
            self.slots.len(),
        );
        super::with_id(id, &body)
    }

    fn metrics_frame(&self, id: Option<&str>) -> String {
        let mut submitted = 0u64;
        let mut completed = 0u64;
        let mut failed = 0u64;
        let mut canceled = 0u64;
        let mut short_circuits = 0u64;
        let mut live = 0usize;
        let mut per_shard = Vec::with_capacity(self.slots.len());
        for k in 0..self.slots.len() {
            let (addr, pid) = {
                let slot = self.slots[k].lock().expect("shard slot");
                (slot.addr, slot.pid)
            };
            let reply = addr.and_then(|a| one_shot(a, &protocol::control_request("metrics")));
            match reply {
                Some(j) => {
                    live += 1;
                    submitted += get_u64(&j, &["jobs", "submitted"]);
                    completed += get_u64(&j, &["jobs", "completed"]);
                    failed += get_u64(&j, &["jobs", "failed"]);
                    canceled += get_u64(&j, &["jobs", "canceled"]);
                    short_circuits += get_u64(&j, &["jobs", "cache_short_circuits"]);
                    per_shard.push(format!(
                        "{{\"shard\":{k},\"alive\":true,\"pid\":{},\"queue_depth\":{},\
                         \"in_flight\":{},\"cache_hits\":{}}}",
                        pid.unwrap_or(0),
                        get_u64(&j, &["queue_depth"]),
                        get_u64(&j, &["in_flight"]),
                        get_u64(&j, &["cache", "hits"]),
                    ));
                }
                None => per_shard.push(format!("{{\"shard\":{k},\"alive\":false}}")),
            }
        }
        let jobs = format!(
            "{{\"submitted\":{submitted},\"completed\":{completed},\"failed\":{failed},\
             \"canceled\":{canceled},\"cache_short_circuits\":{short_circuits}}}"
        );
        let body = format!(
            "\"event\":\"metrics\",\"shards\":{},\"shards_live\":{live},\
             \"shard_restarts\":{},\"uptime_ms\":{},\"jobs\":{jobs},\"per_shard\":[{}]",
            self.slots.len(),
            self.restarts.load(Ordering::SeqCst),
            self.started.elapsed().as_millis(),
            per_shard.join(","),
        );
        super::with_id(id, &body)
    }

    fn trace_lookup(&self, target: &str) -> Option<String> {
        // at most one shard minted this trace id (or ran this job id
        // last); ask them all and relay the first hit's body verbatim
        let req = protocol::trace_request(target);
        for k in 0..self.slots.len() {
            let Some(addr) = self.slot_addr(k) else { continue };
            let Some(reply) = one_shot_raw(addr, &req) else { continue };
            if let Some(body) = reply
                .strip_prefix("{\"event\":\"trace\",\"found\":true,")
                .and_then(|rest| rest.strip_suffix('}'))
            {
                return Some(body.to_string());
            }
        }
        None
    }

    fn prometheus_text(&self) -> String {
        // one metrics scrape per live shard feeds both the counter sums
        // and the histogram merge
        let mut frames = Vec::with_capacity(self.slots.len());
        for k in 0..self.slots.len() {
            let Some(addr) = self.slot_addr(k) else { continue };
            let Some(j) = one_shot(addr, &protocol::control_request("metrics")) else { continue };
            frames.push(j);
        }
        let live = frames.len();
        let sum = |path: &[&str]| frames.iter().map(|j| get_u64(j, path)).sum::<u64>() as f64;
        let merged = |name: &str| {
            let mut s = hist::Snapshot::default();
            for j in &frames {
                if let Some(h) = j.get("obs").and_then(|o| o.get(name)) {
                    s.merge(&snapshot_from_json(h));
                }
            }
            s
        };
        // the same metric names the solo tier renders, summed across
        // the fleet (keep names in sync with [`super::prometheus_text`];
        // help strings here say "fleet-wide" where the sum spans shards)
        let counters: [(&str, &str, &[&str], f64); 18] = [
            ("alingam_jobs_submitted_total", "Jobs accepted.", &["jobs", "submitted"], 1.0),
            (
                "alingam_jobs_completed_total",
                "Jobs ended in a result.",
                &["jobs", "completed"],
                1.0,
            ),
            ("alingam_jobs_failed_total", "Jobs ended in an error.", &["jobs", "failed"], 1.0),
            ("alingam_jobs_canceled_total", "Jobs canceled.", &["jobs", "canceled"], 1.0),
            (
                "alingam_cache_short_circuits_total",
                "Cached at submit.",
                &["jobs", "cache_short_circuits"],
                1.0,
            ),
            ("alingam_busy_seconds_total", "Summed job wall clock.", &["busy_ms_total"], 1e3),
            ("alingam_cache_hits_total", "Result-cache hits.", &["cache", "hits"], 1.0),
            ("alingam_cache_misses_total", "Result-cache misses.", &["cache", "misses"], 1.0),
            ("alingam_cache_evictions_total", "Cache LRU evictions.", &["cache", "evictions"], 1.0),
            ("alingam_cache_disk_hits_total", "Disk-segment hits.", &["cache", "disk_hits"], 1.0),
            (
                "alingam_cache_eviction_age_seconds_total",
                "Evicted-entry age.",
                &["cache", "eviction_age_ms_total"],
                1e3,
            ),
            ("alingam_sweep_pairs_total", "Candidate sweep pairs.", &["sweep", "pairs_total"], 1.0),
            (
                "alingam_sweep_pairs_visited_total",
                "Pairs scored.",
                &["sweep", "pairs_visited"],
                1.0,
            ),
            (
                "alingam_sweep_pairs_skipped_total",
                "Pairs pruned.",
                &["sweep", "pairs_skipped"],
                1.0,
            ),
            (
                "alingam_partition_blocks_formed_total",
                "Partition blocks.",
                &["partition", "blocks_formed"],
                1.0,
            ),
            (
                "alingam_partition_boundary_pairs_total",
                "Boundary pairs.",
                &["partition", "boundary_pairs"],
                1.0,
            ),
            (
                "alingam_batches_dispatched_total",
                "Fused groups run.",
                &["batch", "batches_dispatched"],
                1.0,
            ),
            ("alingam_jobs_fused_total", "Jobs run fused.", &["batch", "jobs_fused"], 1.0),
        ];
        let gauges: [(&str, &str, &[&str]); 5] = [
            ("alingam_queue_depth", "Queued jobs, fleet-wide.", &["queue_depth"]),
            ("alingam_in_flight", "Executing jobs, fleet-wide.", &["in_flight"]),
            ("alingam_workers", "Worker threads across shards.", &["workers"]),
            ("alingam_cache_entries", "Cache entries, fleet-wide.", &["cache", "entries"]),
            ("alingam_cache_capacity", "Cache capacity, fleet-wide.", &["cache", "capacity"]),
        ];
        let mut p = PromText::new();
        for (name, help, path, div) in counters {
            p.single(name, "counter", help, sum(path) / div);
        }
        for (name, help, path) in gauges {
            p.single(name, "gauge", help, sum(path));
        }
        p.single(
            "alingam_fuse_wait_seconds_total",
            "counter",
            "Total time batch leaders held the fusion window open, in seconds.",
            sum(&["batch", "fuse_wait_ms_total"]) / 1e3,
        );
        p.single(
            "alingam_uptime_seconds",
            "gauge",
            "Seconds since the fleet front started (monotonic clock).",
            self.started.elapsed().as_secs_f64(),
        );
        p.single(
            "alingam_start_time_seconds",
            "gauge",
            "Unix time the fleet front started, in seconds.",
            self.start_unix_ms as f64 / 1e3,
        );
        p.single("alingam_shards", "gauge", "Configured shard slots.", self.slots.len() as f64);
        p.single("alingam_shards_live", "gauge", "Shards answering scrapes.", live as f64);
        p.single(
            "alingam_shard_restarts_total",
            "counter",
            "Shard children restarted after unexpected exits.",
            self.restarts.load(Ordering::SeqCst) as f64,
        );
        p.summary_seconds(
            "alingam_job_latency_seconds",
            "Submit-to-terminal job latency, merged across shards.",
            &merged("job_latency"),
        );
        p.summary_seconds(
            "alingam_queue_wait_seconds",
            "Submit-to-pop queue wait, merged across shards.",
            &merged("queue_wait"),
        );
        p.summary_seconds(
            "alingam_step_seconds",
            "Per-search-step ordering latency, merged across shards.",
            &merged("step"),
        );
        p.render()
    }

    fn cancel(&self, target: &str) -> bool {
        let mut known = false;
        for k in 0..self.slots.len() {
            let Some(addr) = self.slot_addr(k) else { continue };
            if let Some(j) = one_shot(addr, &protocol::cancel_request(target)) {
                known |= j.get("ok").and_then(Json::as_bool).unwrap_or(false);
            }
        }
        known
    }

    fn request_shutdown(&self) {
        let mut stop = self.stop_flag.lock().expect("stop flag");
        *stop = true;
        self.stop_cv.notify_all();
    }

    fn submit(&self, client: u64, raw: &str, spec: protocol::JobSpec, sink: &Sink) {
        // watch subscriptions are stateful streams: their follow-up
        // `frame`/`end` requests route through the in-process watch
        // registry, which a relay front does not host. Accepting the
        // subscribe here would strand the client with a stream it can
        // never feed — reject it up front instead.
        if matches!(spec.kind, protocol::JobKind::Watch { .. }) {
            sink(&protocol::frame_error(
                Some(&spec.id),
                "watch streams are not available through a sharded fleet; \
                 connect to a shard directly",
            ));
            return;
        }
        let n = self.slots.len();
        let preferred = (route_hash(&spec) % n as u64) as usize;
        // preferred shard first (cache affinity), then fail over across
        // the rest of the ring
        for off in 0..n {
            let k = (preferred + off) % n;
            let Some(addr) = self.slot_addr(k) else { continue };
            let Some(link) = self.link_for(client, k, addr, sink) else { continue };
            link.pending.lock().expect("link pending").insert(spec.id.clone());
            let wrote = match link.writer.lock() {
                Ok(mut w) => {
                    w.write_all(raw.as_bytes()).and_then(|()| w.write_all(b"\n")).is_ok()
                }
                Err(_) => false,
            };
            if wrote {
                return;
            }
            // this link is broken; un-book the job (the relay reader
            // must not double-fail it) and try the next shard
            link.pending.lock().expect("link pending").remove(&spec.id);
            link.dead.store(true, Ordering::SeqCst);
        }
        sink(&protocol::frame_error(
            Some(&spec.id),
            "no live shard available to run this job",
        ));
    }

    fn attach(&self, stream: &TcpStream) -> u64 {
        let client = self.next_client.fetch_add(1, Ordering::Relaxed);
        if let Ok(clone) = stream.try_clone() {
            self.conns.lock().expect("conn list").push((client, clone));
        }
        client
    }

    fn detach(&self, client: u64) {
        self.conns.lock().expect("conn list").retain(|(c, _)| *c != client);
        // sever this client's relay links so their reader threads exit
        self.links.lock().expect("shard links").retain(|(c, _), link| {
            if *c == client {
                if let Ok(w) = link.writer.lock() {
                    let _ = w.shutdown(Shutdown::Both);
                }
                false
            } else {
                true
            }
        });
    }

    fn shutting_down(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }
}

/// Spawn one shard process and wait for its "serving on ADDR" readiness
/// line. The rest of the child's stdout is drained to a sink thread so
/// the pipe can never fill and block it.
fn spawn_shard(fleet: &Fleet, k: usize) -> Result<(Child, SocketAddr, u32)> {
    let mut cmd = Command::new(&fleet.exe);
    cmd.arg("serve").arg("--addr").arg("127.0.0.1:0");
    cmd.args(&fleet.child_args);
    if let Some(dir) = &fleet.cache_dir {
        cmd.arg("--cache-dir").arg(dir.join(format!("shard-{k}")));
    }
    cmd.stdin(Stdio::null()).stdout(Stdio::piped()).stderr(Stdio::null());
    let mut child = cmd.spawn()?;
    let pid = child.id();
    let stdout = child.stdout.take().ok_or_else(|| {
        Error::Runtime(format!("shard {k}: no stdout pipe from child process"))
    })?;
    let mut reader = BufReader::new(stdout);
    let addr = loop {
        let mut line = String::new();
        let got = reader.read_line(&mut line)?;
        if got == 0 {
            let _ = child.kill();
            let _ = child.wait();
            return Err(Error::Runtime(format!(
                "shard {k}: child exited before announcing its address"
            )));
        }
        if let Some(rest) = line.trim().strip_prefix("serving on ") {
            match rest.parse::<SocketAddr>() {
                Ok(a) => break a,
                Err(_) => {
                    let _ = child.kill();
                    let _ = child.wait();
                    return Err(Error::Runtime(format!(
                        "shard {k}: unparseable announce line {rest:?}"
                    )));
                }
            }
        }
    };
    let _ = thread::Builder::new().name(format!("shard-drain-{k}")).spawn(move || {
        let _ = std::io::copy(&mut reader, &mut std::io::sink());
    });
    Ok((child, addr, pid))
}

/// Watch one slot: when its child exits unexpectedly, book the restart,
/// fail that shard's in-flight relay jobs, and respawn with backoff.
fn monitor_loop(fleet: &Arc<Fleet>, k: usize) {
    let mut backoff = BACKOFF_START;
    loop {
        if fleet.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let exited = {
            let mut slot = fleet.slots[k].lock().expect("shard slot");
            match slot.child.as_mut() {
                Some(child) => match child.try_wait() {
                    Ok(Some(_status)) => true,
                    Ok(None) => false,
                    Err(_) => true,
                },
                None => true,
            }
        };
        if !exited {
            backoff = BACKOFF_START;
            thread::sleep(MONITOR_POLL);
            continue;
        }
        // mark dead first so routing fails over immediately
        {
            let mut slot = fleet.slots[k].lock().expect("shard slot");
            slot.addr = None;
            slot.pid = None;
            if let Some(mut child) = slot.child.take() {
                let _ = child.wait();
            }
        }
        log::warn("shard_exited", &[("shard", &k.to_string())]);
        // sever this shard's relay links: their reader threads see EOF
        // and fail the pending jobs with terminal error frames
        fleet.links.lock().expect("shard links").retain(|(_, shard), link| {
            if *shard == k {
                if let Ok(w) = link.writer.lock() {
                    let _ = w.shutdown(Shutdown::Both);
                }
                false
            } else {
                true
            }
        });
        // backoff in small increments so shutdown stays responsive
        let mut waited = Duration::ZERO;
        while waited < backoff {
            if fleet.shutdown.load(Ordering::SeqCst) {
                return;
            }
            let step = MONITOR_POLL.min(backoff - waited);
            thread::sleep(step);
            waited += step;
        }
        if fleet.shutdown.load(Ordering::SeqCst) {
            return;
        }
        match spawn_shard(fleet, k) {
            Ok((child, addr, pid)) => {
                let mut slot = fleet.slots[k].lock().expect("shard slot");
                slot.addr = Some(addr);
                slot.pid = Some(pid);
                slot.child = Some(child);
                drop(slot);
                fleet.restarts.fetch_add(1, Ordering::SeqCst);
                log::info(
                    "shard_restarted",
                    &[
                        ("shard", &k.to_string()),
                        ("pid", &pid.to_string()),
                        ("addr", &addr.to_string()),
                    ],
                );
                backoff = BACKOFF_START;
            }
            Err(_) => {
                backoff = (backoff * 2).min(BACKOFF_CAP);
            }
        }
    }
}

/// A running fleet: front listener(s) + shard children + monitors.
/// Create with [`Supervisor::start`], stop with
/// [`Supervisor::shutdown_within`].
pub struct Supervisor {
    addr: SocketAddr,
    http_addr: Option<SocketAddr>,
    fleet: Arc<Fleet>,
    accept: Option<JoinHandle<()>>,
    http_accept: Option<JoinHandle<()>>,
    monitors: Vec<JoinHandle<()>>,
}

impl Supervisor {
    /// Bind the front, spawn `shards` children of `exe` (defaults to
    /// the current executable), wait for each to announce its address,
    /// start the monitors and accept loops.
    pub fn start(cfg: ServeConfig, shards: usize, exe: Option<PathBuf>) -> Result<Supervisor> {
        if shards < 2 {
            return Err(Error::InvalidArgument(format!(
                "a sharded fleet needs at least 2 shards, got {shards}"
            )));
        }
        let exe = match exe {
            Some(p) => p,
            None => std::env::current_exe()?,
        };
        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        let http_listener = match &cfg.http_addr {
            Some(a) => Some(TcpListener::bind(a)?),
            None => None,
        };
        let http_addr = match &http_listener {
            Some(l) => Some(l.local_addr()?),
            None => None,
        };
        let mut child_args = vec![
            "--serve-workers".to_string(),
            cfg.workers.to_string(),
            "--queue-cap".to_string(),
            cfg.queue_capacity.to_string(),
            "--cache-entries".to_string(),
            cfg.cache_entries.to_string(),
            "--fuse-wait-ms".to_string(),
            cfg.fuse_wait_ms.to_string(),
            "--max-batch".to_string(),
            cfg.max_batch.to_string(),
            "--log-level".to_string(),
            cfg.log_level.clone(),
        ];
        if cfg.log_json {
            child_args.push("--log-json".to_string());
        }
        let fleet = Arc::new(Fleet {
            slots: (0..shards).map(|_| Mutex::new(Slot::default())).collect(),
            restarts: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            stop_flag: Mutex::new(false),
            stop_cv: Condvar::new(),
            conns: Mutex::new(Vec::new()),
            next_client: AtomicU64::new(1),
            started: Instant::now(),
            start_unix_ms: super::unix_millis_now(),
            links: Mutex::new(HashMap::new()),
            exe,
            child_args,
            cache_dir: cfg.cache_dir.clone(),
        });
        for k in 0..shards {
            match spawn_shard(&fleet, k) {
                Ok((child, shard_addr, pid)) => {
                    let mut slot = fleet.slots[k].lock().expect("shard slot");
                    slot.addr = Some(shard_addr);
                    slot.pid = Some(pid);
                    slot.child = Some(child);
                    drop(slot);
                    log::info(
                        "shard_started",
                        &[
                            ("shard", &k.to_string()),
                            ("pid", &pid.to_string()),
                            ("addr", &shard_addr.to_string()),
                        ],
                    );
                }
                Err(e) => {
                    // roll back the shards already spawned
                    for slot in &fleet.slots {
                        let mut slot = slot.lock().expect("shard slot");
                        if let Some(mut child) = slot.child.take() {
                            let _ = child.kill();
                            let _ = child.wait();
                        }
                    }
                    return Err(e);
                }
            }
        }
        let monitors = (0..shards)
            .map(|k| {
                let f = fleet.clone();
                thread::Builder::new()
                    .name(format!("shard-monitor-{k}"))
                    .spawn(move || monitor_loop(&f, k))
                    .expect("spawn shard monitor")
            })
            .collect();
        let accept = {
            let backend: Arc<dyn Backend> = fleet.clone();
            thread::Builder::new()
                .name("fleet-accept".to_string())
                .spawn(move || super::accept_loop(listener, backend, false))
                .expect("spawn fleet acceptor")
        };
        let http_accept = http_listener.map(|l| {
            let backend: Arc<dyn Backend> = fleet.clone();
            thread::Builder::new()
                .name("fleet-http-accept".to_string())
                .spawn(move || super::accept_loop(l, backend, true))
                .expect("spawn fleet http acceptor")
        });
        Ok(Supervisor { addr, http_addr, fleet, accept: Some(accept), http_accept, monitors })
    }

    /// The front's bound TCP address.
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The front's bound HTTP address, when enabled.
    pub fn http_local_addr(&self) -> Option<SocketAddr> {
        self.http_addr
    }

    /// Live shards as (index, pid, address) — the CLI prints these so
    /// operators (and the CI smoke) can target a specific shard.
    pub fn shard_table(&self) -> Vec<(usize, u32, SocketAddr)> {
        let mut out = Vec::new();
        for (k, slot) in self.fleet.slots.iter().enumerate() {
            let slot = slot.lock().expect("shard slot");
            if let (Some(pid), Some(addr)) = (slot.pid, slot.addr) {
                out.push((k, pid, addr));
            }
        }
        out
    }

    /// Restarts booked so far (tests; clients use the `metrics` frame).
    pub fn restart_count(&self) -> u64 {
        self.fleet.restarts.load(Ordering::SeqCst)
    }

    /// Block until some client sends a `shutdown` frame.
    pub fn wait_for_shutdown_request(&self) {
        let mut stop = self.fleet.stop_flag.lock().expect("stop flag");
        while !*stop {
            stop = self.fleet.stop_cv.wait(stop).expect("stop flag");
        }
    }

    /// Stop the fleet: ask every shard to drain gracefully, wait up to
    /// `limit` for the children to exit, kill whatever remains, then
    /// sever front connections. Returns `true` when every child exited
    /// by itself within the limit.
    pub fn shutdown_within(mut self, limit: Duration) -> bool {
        self.fleet.shutdown.store(true, Ordering::SeqCst);
        // ask each live shard to drain and exit
        for slot in &self.fleet.slots {
            let addr = slot.lock().expect("shard slot").addr;
            if let Some(addr) = addr {
                let _ = one_shot(addr, &protocol::control_request("shutdown"));
            }
        }
        // poke the acceptors awake and join them
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.http_addr {
            let _ = TcpStream::connect(a);
        }
        if let Some(handle) = self.accept.take() {
            let _ = handle.join();
        }
        if let Some(handle) = self.http_accept.take() {
            let _ = handle.join();
        }
        for handle in self.monitors.drain(..) {
            let _ = handle.join();
        }
        // bounded wait for the children to drain and exit
        let deadline = Instant::now() + limit;
        let mut clean = true;
        for slot in &self.fleet.slots {
            let mut slot = slot.lock().expect("shard slot");
            let Some(child) = slot.child.as_mut() else { continue };
            loop {
                match child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) => {
                        if Instant::now() >= deadline {
                            clean = false;
                            let _ = child.kill();
                            let _ = child.wait();
                            break;
                        }
                        thread::sleep(Duration::from_millis(10));
                    }
                    Err(_) => break,
                }
            }
            slot.child = None;
            slot.addr = None;
            slot.pid = None;
        }
        // sever relay links and front connections
        for (_, link) in self.fleet.links.lock().expect("shard links").drain() {
            if let Ok(w) = link.writer.lock() {
                let _ = w.shutdown(Shutdown::Both);
            }
        }
        for (_client, conn) in self.fleet.conns.lock().expect("conn list").drain(..) {
            let _ = conn.shutdown(Shutdown::Both);
        }
        clean
    }

    /// [`Supervisor::shutdown_within`] with a 10-minute bound.
    pub fn shutdown(self) {
        let _ = self.shutdown_within(Duration::from_secs(600));
    }
}

/// Render the shard table as the human lines `serve` prints at boot
/// (the CI smoke greps pids out of these).
pub fn shard_banner(table: &[(usize, u32, SocketAddr)]) -> String {
    table
        .iter()
        .map(|(k, pid, addr)| format!("shard {k} serving on {addr} (pid {pid})"))
        .collect::<Vec<_>>()
        .join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn spec(panel: protocol::PanelSource) -> protocol::JobSpec {
        protocol::JobSpec {
            id: "t".to_string(),
            panel,
            engine: "vectorized".to_string(),
            kind: protocol::JobKind::Fit,
            trace: 0,
        }
    }

    #[test]
    fn route_hash_is_stable_and_panel_sensitive() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 5.0]]);
        let ha = route_hash(&spec(protocol::PanelSource::Inline(a.clone())));
        assert_eq!(
            ha,
            route_hash(&spec(protocol::PanelSource::Inline(a))),
            "identical panels route identically"
        );
        assert_ne!(
            ha,
            route_hash(&spec(protocol::PanelSource::Inline(b))),
            "different panels should (overwhelmingly) route differently"
        );
        assert_ne!(
            route_hash(&spec(protocol::PanelSource::Csv("/a.csv".into()))),
            route_hash(&spec(protocol::PanelSource::Csv("/b.csv".into()))),
        );
    }

    #[test]
    fn terminal_id_extracts_ids_only_from_terminal_frames() {
        assert_eq!(
            terminal_id(&protocol::frame_result(Some("a"), false, 1.0, "{}")),
            Some("a".to_string())
        );
        assert_eq!(terminal_id(&protocol::frame_canceled("b")), Some("b".to_string()));
        assert_eq!(terminal_id(&protocol::frame_error(None, "x")), Some(String::new()));
        assert_eq!(terminal_id(&protocol::frame_accepted("a", 0)), None);
        assert_eq!(terminal_id("garbage"), None);
    }

    #[test]
    fn shard_banner_lines_carry_index_pid_and_addr() {
        let table =
            vec![(0usize, 41u32, "127.0.0.1:5001".parse().unwrap()), (1, 42, "127.0.0.1:5002".parse().unwrap())];
        let banner = shard_banner(&table);
        assert!(banner.contains("shard 0 serving on 127.0.0.1:5001 (pid 41)"));
        assert!(banner.contains("shard 1 serving on 127.0.0.1:5002 (pid 42)"));
    }
}
