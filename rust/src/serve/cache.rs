//! Panel-hash result cache: repeated fits on overlapping workloads are
//! the serve layer's cheapest speedup — a byte-identical request (same
//! panel bits, same effective engine spec, same options) returns the
//! previously computed result payload without touching a worker session.
//!
//! Keys are 128-bit FNV-1a digests over the request's full semantic
//! content (job kind + options, canonical engine spec, panel dims and
//! every sample's bit pattern), streamed through [`Fnv128`] so the panel
//! is never re-serialized just to be hashed. 128 bits makes accidental
//! collisions negligible (~2⁻⁶⁴ at a billion entries); the cache is a
//! correctness-relevant map, which is why the 64-bit hash the repo uses
//! for property seeds is not enough here.
//!
//! The store is a mutex-guarded MRU-ordered vector — an LRU for the
//! two-digit capacities a discovery service wants (results are large,
//! panels larger; the win is in skipping recomputation, not in hoarding
//! thousands of entries), with hit/miss/eviction counters feeding
//! [`ServeMetrics`](super::ServeMetrics).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Streaming 128-bit FNV-1a hasher.
pub struct Fnv128 {
    state: u128,
}

impl Fnv128 {
    const OFFSET: u128 = 0x6c62_272e_07bb_0142_62b8_2175_6295_c58d;
    const PRIME: u128 = 0x0000_0000_0100_0000_0000_0000_0000_013b;

    pub fn new() -> Fnv128 {
        Fnv128 { state: Self::OFFSET }
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= b as u128;
            self.state = self.state.wrapping_mul(Self::PRIME);
        }
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    /// Hash the exact bit pattern (so −0.0 ≠ 0.0 and every NaN payload
    /// is distinct — byte-identical panels, not value-equal ones).
    pub fn write_f64_bits(&mut self, v: f64) {
        self.write_u64(v.to_bits());
    }

    /// Length-prefixed, so `("ab","c")` and `("a","bc")` differ.
    pub fn write_str(&mut self, s: &str) {
        self.write_u64(s.len() as u64);
        self.write(s.as_bytes());
    }

    pub fn finish(&self) -> u128 {
        self.state
    }
}

impl Default for Fnv128 {
    fn default() -> Self {
        Fnv128::new()
    }
}

/// A snapshot of the cache's counters (for the `metrics` frame).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub entries: usize,
    pub capacity: usize,
}

impl CacheStats {
    /// Hits over lookups (1.0 when nothing has been looked up).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            1.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// LRU result cache keyed by [`Fnv128`] digests, storing the serialized
/// `data` payload of a result frame (shared via `Arc` so a hit costs a
/// pointer clone, not a payload copy). `capacity == 0` disables caching
/// entirely (every lookup is a miss, nothing is stored).
pub struct ResultCache {
    /// MRU-first: index 0 is the most recently used entry.
    entries: Mutex<Vec<(u128, Arc<String>)>>,
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl ResultCache {
    pub fn new(capacity: usize) -> ResultCache {
        ResultCache {
            entries: Mutex::new(Vec::new()),
            capacity,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Look a key up, promoting it to most-recently-used on a hit.
    pub fn get(&self, key: u128) -> Option<Arc<String>> {
        let mut entries = self.entries.lock().expect("result cache");
        match entries.iter().position(|(k, _)| *k == key) {
            Some(pos) => {
                let entry = entries.remove(pos);
                let value = entry.1.clone();
                entries.insert(0, entry);
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(value)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert (or refresh) a key, evicting from the LRU end past
    /// capacity.
    pub fn put(&self, key: u128, value: Arc<String>) {
        if self.capacity == 0 {
            return;
        }
        let mut entries = self.entries.lock().expect("result cache");
        if let Some(pos) = entries.iter().position(|(k, _)| *k == key) {
            entries.remove(pos);
        }
        entries.insert(0, (key, value));
        while entries.len() > self.capacity {
            entries.pop();
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: self.entries.lock().expect("result cache").len(),
            capacity: self.capacity,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(s: &str) -> Arc<String> {
        Arc::new(s.to_string())
    }

    #[test]
    fn hashes_separate_fields_and_bit_patterns() {
        let digest = |f: &dyn Fn(&mut Fnv128)| {
            let mut h = Fnv128::new();
            f(&mut h);
            h.finish()
        };
        // length prefixes keep field boundaries distinct
        let ab_c = digest(&|h| {
            h.write_str("ab");
            h.write_str("c");
        });
        let a_bc = digest(&|h| {
            h.write_str("a");
            h.write_str("bc");
        });
        assert_ne!(ab_c, a_bc);
        // bit-pattern hashing distinguishes −0.0 from 0.0
        assert_ne!(
            digest(&|h| h.write_f64_bits(0.0)),
            digest(&|h| h.write_f64_bits(-0.0))
        );
        // deterministic
        assert_eq!(digest(&|h| h.write_u64(42)), digest(&|h| h.write_u64(42)));
    }

    #[test]
    fn hit_miss_counters_and_payload_sharing() {
        let c = ResultCache::new(4);
        assert!(c.get(1).is_none());
        c.put(1, v("one"));
        let got = c.get(1).expect("hit");
        assert_eq!(*got, "one");
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn evicts_least_recently_used_and_touch_protects() {
        let c = ResultCache::new(2);
        c.put(1, v("1"));
        c.put(2, v("2"));
        // touch 1 so it becomes MRU; inserting 3 must evict 2
        assert!(c.get(1).is_some());
        c.put(3, v("3"));
        assert!(c.get(2).is_none(), "LRU entry must have been evicted");
        assert!(c.get(1).is_some());
        assert!(c.get(3).is_some());
        let s = c.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.entries, 2);
    }

    #[test]
    fn refresh_replaces_value_without_growth() {
        let c = ResultCache::new(2);
        c.put(7, v("old"));
        c.put(7, v("new"));
        assert_eq!(*c.get(7).unwrap(), "new");
        assert_eq!(c.stats().entries, 1);
        assert_eq!(c.stats().evictions, 0);
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let c = ResultCache::new(0);
        c.put(1, v("x"));
        assert!(c.get(1).is_none());
        assert_eq!(c.stats().entries, 0);
    }

    #[test]
    fn empty_hit_rate_is_one() {
        assert_eq!(ResultCache::new(2).stats().hit_rate(), 1.0);
    }
}
