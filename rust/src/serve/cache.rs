//! Panel-hash result cache: repeated fits on overlapping workloads are
//! the serve layer's cheapest speedup — a byte-identical request (same
//! panel bits, same effective engine spec, same options) returns the
//! previously computed result payload without touching a worker session.
//!
//! Keys are 128-bit FNV-1a digests over the request's full semantic
//! content (job kind + options, canonical engine spec, panel dims and
//! every sample's bit pattern), streamed through [`Fnv128`] so the panel
//! is never re-serialized just to be hashed. 128 bits makes accidental
//! collisions negligible (~2⁻⁶⁴ at a billion entries); the cache is a
//! correctness-relevant map, which is why the 64-bit hash the repo uses
//! for property seeds is not enough here.
//!
//! The store is a mutex-guarded MRU-ordered vector — an LRU for the
//! two-digit capacities a discovery service wants (results are large,
//! panels larger; the win is in skipping recomputation, not in hoarding
//! thousands of entries), with hit/miss/eviction counters feeding
//! [`ServeMetrics`](super::ServeMetrics).
//!
//! # Disk persistence
//!
//! With [`ResultCache::with_dir`] the cache spills to an append-only
//! segment file (`results.seg`) so byte-identical repeat traffic
//! survives a server restart: every [`ResultCache::put`] appends one
//! checksummed record and `fsync`s it (`sync_data` — a result that was
//! acknowledged cached is never lost to a crash), and opening replays
//! the log with later records winning. Recovery is crash-tolerant: a
//! torn tail — a record cut short by a crash mid-append, or bytes whose
//! checksum does not match — drops exactly the partial record and
//! everything after it, never panicking and never discarding the intact
//! prefix. Each open also compacts: the surviving records (deduped,
//! capped at capacity) are rewritten through a temp file renamed into
//! place, so the log cannot grow without bound across restarts and the
//! corrupt tail is physically truncated away. Entries revived from disk
//! are flagged, so the `disk_hits` / `recovered` counters (and the
//! cumulative `eviction_age_ms_total`, the age-at-eviction metric) make
//! restart traffic observable in the `metrics` frame.

use std::fs::{self, File, OpenOptions};
use std::io::Write as _;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Streaming 128-bit FNV-1a hasher.
pub struct Fnv128 {
    state: u128,
}

impl Fnv128 {
    const OFFSET: u128 = 0x6c62_272e_07bb_0142_62b8_2175_6295_c58d;
    const PRIME: u128 = 0x0000_0000_0100_0000_0000_0000_0000_013b;

    pub fn new() -> Fnv128 {
        Fnv128 { state: Self::OFFSET }
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= b as u128;
            self.state = self.state.wrapping_mul(Self::PRIME);
        }
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    /// Hash the exact bit pattern (so −0.0 ≠ 0.0 and every NaN payload
    /// is distinct — byte-identical panels, not value-equal ones).
    pub fn write_f64_bits(&mut self, v: f64) {
        self.write_u64(v.to_bits());
    }

    /// Length-prefixed, so `("ab","c")` and `("a","bc")` differ.
    pub fn write_str(&mut self, s: &str) {
        self.write_u64(s.len() as u64);
        self.write(s.as_bytes());
    }

    pub fn finish(&self) -> u128 {
        self.state
    }
}

impl Default for Fnv128 {
    fn default() -> Self {
        Fnv128::new()
    }
}

/// A snapshot of the cache's counters (for the `metrics` frame).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub entries: usize,
    pub capacity: usize,
    /// Hits answered by an entry recovered from the disk segment (a
    /// subset of `hits`).
    pub disk_hits: u64,
    /// Entries replayed from the segment file at open.
    pub recovered: u64,
    /// Cumulative in-memory age, in milliseconds, of every evicted
    /// entry at the moment it was evicted — monotonically
    /// non-decreasing, so eviction churn (young entries being pushed
    /// out) is visible as a low age-per-eviction ratio.
    pub eviction_age_ms_total: u64,
}

impl CacheStats {
    /// Hits over lookups (1.0 when nothing has been looked up).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            1.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Mean in-memory age of an evicted entry, in milliseconds — the
    /// derived metric `eviction_age_ms_total` exists for, computable
    /// now that the eviction *count* ships alongside the age total.
    /// 0.0 when nothing has been evicted. Replay-time drops (entries
    /// discarded at open because the segment held more than capacity)
    /// count as evictions with zero in-memory age, so they pull the
    /// mean down rather than silently vanishing.
    pub fn mean_eviction_age_ms(&self) -> f64 {
        if self.evictions == 0 {
            0.0
        } else {
            self.eviction_age_ms_total as f64 / self.evictions as f64
        }
    }
}

/// One cached result and its bookkeeping.
struct Entry {
    key: u128,
    value: Arc<String>,
    /// When this entry (last) entered the in-memory store — the basis
    /// of the age-at-eviction metric.
    inserted: Instant,
    /// Revived from the disk segment at open (hits on it count as
    /// `disk_hits`).
    from_disk: bool,
}

/// The on-disk segment format: an 8-byte magic header, then records of
/// `key (16 LE) · payload length (8 LE) · payload · digest (16 LE)`
/// where the digest is FNV-128 over key, length and payload. Anything
/// that fails these checks ends replay at that offset.
const SEG_MAGIC: &[u8; 8] = b"ALNGSEG1";
/// Segment file name inside the `--cache-dir` directory.
pub const SEG_FILE: &str = "results.seg";

fn record_digest(key: u128, payload: &[u8]) -> u128 {
    let mut h = Fnv128::new();
    h.write(&key.to_le_bytes());
    h.write_u64(payload.len() as u64);
    h.write(payload);
    h.finish()
}

fn write_record(f: &mut File, key: u128, payload: &[u8]) -> std::io::Result<()> {
    f.write_all(&key.to_le_bytes())?;
    f.write_all(&(payload.len() as u64).to_le_bytes())?;
    f.write_all(payload)?;
    f.write_all(&record_digest(key, payload).to_le_bytes())
}

/// Replay a segment image: every intact record in append order,
/// stopping (without error) at the first truncated or corrupt one — the
/// crash-tolerant torn-tail recovery.
fn read_segment(bytes: &[u8]) -> Vec<(u128, String)> {
    let mut out = Vec::new();
    if bytes.len() < SEG_MAGIC.len() || &bytes[..SEG_MAGIC.len()] != SEG_MAGIC {
        return out;
    }
    let mut i = SEG_MAGIC.len();
    while i < bytes.len() {
        if bytes.len() - i < 24 {
            break;
        }
        let key = u128::from_le_bytes(bytes[i..i + 16].try_into().expect("16-byte key"));
        let len = u64::from_le_bytes(bytes[i + 16..i + 24].try_into().expect("8-byte len"));
        // the length is attacker/corruption-controlled: bounds-check it
        // against what is actually on disk before any slicing
        let Ok(len) = usize::try_from(len) else { break };
        let after_header = i + 24;
        if bytes.len() - after_header < len.saturating_add(16) {
            break;
        }
        let payload = &bytes[after_header..after_header + len];
        let digest_at = after_header + len;
        let digest =
            u128::from_le_bytes(bytes[digest_at..digest_at + 16].try_into().expect("digest"));
        if digest != record_digest(key, payload) {
            break;
        }
        let Ok(text) = std::str::from_utf8(payload) else { break };
        out.push((key, text.to_string()));
        i = digest_at + 16;
    }
    out
}

/// Rewrite the segment with exactly `records` (oldest first), via a
/// temp file renamed into place so a crash mid-compaction leaves either
/// the old or the new segment, never a half-written one. Returns the
/// open handle, positioned at end for appends.
fn write_segment(path: &Path, records: &[(u128, String)]) -> std::io::Result<File> {
    let tmp = path.with_extension("seg.tmp");
    let mut f = OpenOptions::new().write(true).create(true).truncate(true).open(&tmp)?;
    f.write_all(SEG_MAGIC)?;
    for (key, payload) in records {
        write_record(&mut f, *key, payload.as_bytes())?;
    }
    f.sync_data()?;
    fs::rename(&tmp, path)?;
    Ok(f)
}

/// LRU result cache keyed by [`Fnv128`] digests, storing the serialized
/// `data` payload of a result frame (shared via `Arc` so a hit costs a
/// pointer clone, not a payload copy). `capacity == 0` disables caching
/// entirely (every lookup is a miss, nothing is stored). With
/// [`ResultCache::with_dir`] the store is backed by an fsynced
/// append-only segment file and survives restarts (see the module
/// docs).
pub struct ResultCache {
    /// MRU-first: index 0 is the most recently used entry.
    entries: Mutex<Vec<Entry>>,
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    disk_hits: AtomicU64,
    eviction_age_ms_total: AtomicU64,
    /// Entries replayed at open (fixed for the cache's lifetime).
    recovered: u64,
    /// Append handle on the segment file; `None` for memory-only
    /// caches. Held on its own mutex so an fsyncing put never blocks
    /// concurrent lookups.
    disk: Option<Mutex<File>>,
}

impl ResultCache {
    /// Memory-only cache (no persistence).
    pub fn new(capacity: usize) -> ResultCache {
        ResultCache {
            entries: Mutex::new(Vec::new()),
            capacity,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            disk_hits: AtomicU64::new(0),
            eviction_age_ms_total: AtomicU64::new(0),
            recovered: 0,
            disk: None,
        }
    }

    /// Disk-backed cache: replay `dir/results.seg` (later records win,
    /// torn tail dropped), compact it, and append every future put with
    /// an fsync. `capacity == 0` still disables caching entirely — the
    /// disk is not touched.
    pub fn with_dir(capacity: usize, dir: &Path) -> crate::util::Result<ResultCache> {
        if capacity == 0 {
            return Ok(ResultCache::new(0));
        }
        fs::create_dir_all(dir)?;
        let path = dir.join(SEG_FILE);
        let bytes = fs::read(&path).unwrap_or_default();
        // later records win: a key re-put with a fresh payload is live
        // under its newest bytes, exactly like the in-memory refresh
        let mut live: Vec<(u128, String)> = Vec::new();
        for (key, payload) in read_segment(&bytes) {
            if let Some(pos) = live.iter().position(|(k, _)| *k == key) {
                live.remove(pos);
            }
            live.push((key, payload));
        }
        // keep the most recent `capacity` (append order is recency
        // order after the dedup above); the drops are evictions that
        // happened to run at open, and are booked as such (with zero
        // in-memory age — the entries never entered this store)
        let drop_n = live.len().saturating_sub(capacity);
        let live = live.split_off(drop_n);
        let file = write_segment(&path, &live)?;
        let now = Instant::now();
        let recovered = live.len() as u64;
        let entries: Vec<Entry> = live
            .into_iter()
            .rev() // newest first ⇒ MRU order
            .map(|(key, payload)| Entry {
                key,
                value: Arc::new(payload),
                inserted: now,
                from_disk: true,
            })
            .collect();
        Ok(ResultCache {
            entries: Mutex::new(entries),
            capacity,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(drop_n as u64),
            disk_hits: AtomicU64::new(0),
            eviction_age_ms_total: AtomicU64::new(0),
            recovered,
            disk: Some(Mutex::new(file)),
        })
    }

    /// Look a key up, promoting it to most-recently-used on a hit.
    pub fn get(&self, key: u128) -> Option<Arc<String>> {
        let mut entries = self.entries.lock().expect("result cache");
        match entries.iter().position(|e| e.key == key) {
            Some(pos) => {
                let entry = entries.remove(pos);
                let value = entry.value.clone();
                if entry.from_disk {
                    self.disk_hits.fetch_add(1, Ordering::Relaxed);
                }
                entries.insert(0, entry);
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(value)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert (or refresh) a key, evicting from the LRU end past
    /// capacity. Disk-backed caches append the record (fsynced) after
    /// the in-memory store is updated; a failing disk degrades to
    /// memory-only behavior rather than failing the job that computed
    /// the result.
    pub fn put(&self, key: u128, value: Arc<String>) {
        if self.capacity == 0 {
            return;
        }
        let mut entries = self.entries.lock().expect("result cache");
        if let Some(pos) = entries.iter().position(|e| e.key == key) {
            entries.remove(pos);
        }
        entries.insert(
            0,
            Entry { key, value: value.clone(), inserted: Instant::now(), from_disk: false },
        );
        while entries.len() > self.capacity {
            if let Some(evicted) = entries.pop() {
                let age = evicted.inserted.elapsed().as_millis() as u64;
                self.eviction_age_ms_total.fetch_add(age, Ordering::Relaxed);
            }
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        // release the entries lock before touching the disk: lookups
        // never wait on an fsync
        drop(entries);
        if let Some(disk) = &self.disk {
            if let Ok(mut f) = disk.lock() {
                let _ = write_record(&mut f, key, value.as_bytes()).and_then(|()| f.sync_data());
            }
        }
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: self.entries.lock().expect("result cache").len(),
            capacity: self.capacity,
            disk_hits: self.disk_hits.load(Ordering::Relaxed),
            recovered: self.recovered,
            eviction_age_ms_total: self.eviction_age_ms_total.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(s: &str) -> Arc<String> {
        Arc::new(s.to_string())
    }

    #[test]
    fn hashes_separate_fields_and_bit_patterns() {
        let digest = |f: &dyn Fn(&mut Fnv128)| {
            let mut h = Fnv128::new();
            f(&mut h);
            h.finish()
        };
        // length prefixes keep field boundaries distinct
        let ab_c = digest(&|h| {
            h.write_str("ab");
            h.write_str("c");
        });
        let a_bc = digest(&|h| {
            h.write_str("a");
            h.write_str("bc");
        });
        assert_ne!(ab_c, a_bc);
        // bit-pattern hashing distinguishes −0.0 from 0.0
        assert_ne!(
            digest(&|h| h.write_f64_bits(0.0)),
            digest(&|h| h.write_f64_bits(-0.0))
        );
        // deterministic
        assert_eq!(digest(&|h| h.write_u64(42)), digest(&|h| h.write_u64(42)));
    }

    #[test]
    fn hit_miss_counters_and_payload_sharing() {
        let c = ResultCache::new(4);
        assert!(c.get(1).is_none());
        c.put(1, v("one"));
        let got = c.get(1).expect("hit");
        assert_eq!(*got, "one");
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn evicts_least_recently_used_and_touch_protects() {
        let c = ResultCache::new(2);
        c.put(1, v("1"));
        c.put(2, v("2"));
        // touch 1 so it becomes MRU; inserting 3 must evict 2
        assert!(c.get(1).is_some());
        c.put(3, v("3"));
        assert!(c.get(2).is_none(), "LRU entry must have been evicted");
        assert!(c.get(1).is_some());
        assert!(c.get(3).is_some());
        let s = c.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.entries, 2);
    }

    #[test]
    fn refresh_replaces_value_without_growth() {
        let c = ResultCache::new(2);
        c.put(7, v("old"));
        c.put(7, v("new"));
        assert_eq!(*c.get(7).unwrap(), "new");
        assert_eq!(c.stats().entries, 1);
        assert_eq!(c.stats().evictions, 0);
    }

    #[test]
    fn mean_eviction_age_is_computable_and_nan_free() {
        let c = ResultCache::new(1);
        assert_eq!(c.stats().mean_eviction_age_ms(), 0.0, "no evictions yet");
        c.put(1, v("1"));
        std::thread::sleep(std::time::Duration::from_millis(3));
        c.put(2, v("2")); // evicts 1 at age ≥ 3ms
        let s = c.stats();
        assert_eq!(s.evictions, 1);
        let mean = s.mean_eviction_age_ms();
        assert!(mean.is_finite() && mean >= 3.0, "mean age {mean}");
        assert!((mean - s.eviction_age_ms_total as f64).abs() < 1e-9);
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let c = ResultCache::new(0);
        c.put(1, v("x"));
        assert!(c.get(1).is_none());
        assert_eq!(c.stats().entries, 0);
    }

    #[test]
    fn empty_hit_rate_is_one() {
        assert_eq!(ResultCache::new(2).stats().hit_rate(), 1.0);
    }
}
