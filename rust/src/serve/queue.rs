//! The bounded job queue: backpressure for producers, FIFO-per-client
//! fairness for consumers, graceful drain on shutdown.
//!
//! Jobs live in per-client *lanes* (a `VecDeque` each). Consumers
//! round-robin across lanes, so one client queueing a hundred panels
//! cannot starve another's single fit — and a lane is skipped while one
//! of its jobs is in flight, which serializes each client's work:
//! results stream back in exactly the order that client submitted them
//! (the per-client FIFO the integration suite pins), while different
//! clients still run concurrently across workers.
//!
//! [`JobQueue::push`] blocks while the queue is at capacity — real
//! backpressure: the connection reader stalls, the client's TCP writes
//! stall, and the client slows down, instead of the server buffering
//! unboundedly. [`JobQueue::close`] stops accepting new work but lets
//! consumers drain everything already queued; once empty, every
//! [`JobQueue::pop`] returns `None` and the workers exit.

use crate::util::{Error, Result};
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// One client's pending jobs.
struct Lane<T> {
    client: u64,
    jobs: VecDeque<T>,
    /// A popped job from this lane has not been marked done yet; the
    /// lane is ineligible until [`JobQueue::done`] is called, which is
    /// what makes per-client execution (and thus result order) FIFO.
    in_flight: bool,
}

struct State<T> {
    lanes: Vec<Lane<T>>,
    /// Round-robin start position for the next pop.
    cursor: usize,
    /// Queued (not yet popped) jobs across all lanes.
    len: usize,
    open: bool,
}

/// Bounded multi-producer multi-consumer queue with per-client lanes
/// (see module docs).
pub struct JobQueue<T> {
    state: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

impl<T> JobQueue<T> {
    /// `capacity` is the total queued-job bound across all clients
    /// (must be ≥ 1).
    pub fn new(capacity: usize) -> JobQueue<T> {
        assert!(capacity >= 1, "queue capacity must be ≥ 1");
        JobQueue {
            state: Mutex::new(State { lanes: Vec::new(), cursor: 0, len: 0, open: true }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        }
    }

    /// Enqueue a job for `client`, blocking while the queue is full
    /// (backpressure). Errors once the queue has been closed.
    pub fn push(&self, client: u64, job: T) -> Result<()> {
        let mut s = self.state.lock().expect("job queue");
        while s.open && s.len >= self.capacity {
            s = self.not_full.wait(s).expect("job queue");
        }
        if !s.open {
            return Err(Error::InvalidArgument(
                "job queue is shut down: request rejected".into(),
            ));
        }
        match s.lanes.iter_mut().find(|l| l.client == client) {
            Some(lane) => lane.jobs.push_back(job),
            None => s.lanes.push(Lane {
                client,
                jobs: VecDeque::from([job]),
                in_flight: false,
            }),
        }
        s.len += 1;
        self.not_empty.notify_one();
        Ok(())
    }

    /// Dequeue the next job, blocking while nothing is eligible. Lanes
    /// are visited round-robin; a lane with an in-flight job is skipped.
    /// Returns `None` only after [`close`](JobQueue::close) once every
    /// queued job has been handed out.
    pub fn pop(&self) -> Option<(u64, T)> {
        let mut s = self.state.lock().expect("job queue");
        loop {
            let nl = s.lanes.len();
            for k in 0..nl {
                let li = (s.cursor + k) % nl;
                let lane = &mut s.lanes[li];
                if !lane.in_flight && !lane.jobs.is_empty() {
                    let job = lane.jobs.pop_front().expect("non-empty lane");
                    let client = lane.client;
                    lane.in_flight = true;
                    s.cursor = (li + 1) % nl;
                    s.len -= 1;
                    self.not_full.notify_one();
                    return Some((client, job));
                }
            }
            if !s.open && s.len == 0 {
                return None;
            }
            s = self.not_empty.wait(s).expect("job queue");
        }
    }

    /// Mark `client`'s in-flight job finished, making its next queued
    /// job eligible. Workers must call this after completing (or
    /// skipping) every popped job.
    pub fn done(&self, client: u64) {
        let mut s = self.state.lock().expect("job queue");
        if let Some(pos) = s.lanes.iter().position(|l| l.client == client) {
            s.lanes[pos].in_flight = false;
            if s.lanes[pos].jobs.is_empty() {
                // drop the empty lane so the round-robin set stays the
                // set of clients with pending work
                s.lanes.remove(pos);
                if s.cursor > pos {
                    s.cursor -= 1;
                }
                let nl = s.lanes.len();
                s.cursor = if nl == 0 { 0 } else { s.cursor % nl };
            }
        }
        // a lane may have just become eligible: wake all waiters (pops
        // blocked on in-flight lanes, and close() drainers)
        self.not_empty.notify_all();
    }

    /// Stop accepting new jobs; queued jobs still drain. Idempotent.
    pub fn close(&self) {
        {
            let mut s = self.state.lock().expect("job queue");
            s.open = false;
        }
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Jobs queued and not yet handed to a worker.
    pub fn depth(&self) -> usize {
        self.state.lock().expect("job queue").len
    }

    pub fn is_open(&self) -> bool {
        self.state.lock().expect("job queue").open
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn single_client_is_fifo() {
        let q = JobQueue::new(8);
        for j in 0..5 {
            q.push(1, j).unwrap();
        }
        for j in 0..5 {
            let (c, got) = q.pop().unwrap();
            assert_eq!((c, got), (1, j));
            q.done(1);
        }
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn lanes_round_robin_across_clients() {
        let q = JobQueue::new(16);
        // client 1 floods, client 2 submits one job afterwards
        for j in 0..4 {
            q.push(1, (1, j)).unwrap();
        }
        q.push(2, (2, 0)).unwrap();
        let (c1, _) = q.pop().unwrap();
        q.done(c1);
        let (c2, _) = q.pop().unwrap();
        q.done(c2);
        // both clients must have been served within the first two pops
        assert_ne!(c1, c2, "round-robin must alternate clients, got {c1} then {c2}");
    }

    #[test]
    fn in_flight_lane_is_skipped_until_done() {
        let q = JobQueue::new(8);
        q.push(1, "a1").unwrap();
        q.push(1, "a2").unwrap();
        q.push(2, "b1").unwrap();
        let (c, j) = q.pop().unwrap();
        assert_eq!((c, j), (1, "a1"));
        // client 1 has a job in flight: the next pop must serve client 2
        let (c, j) = q.pop().unwrap();
        assert_eq!((c, j), (2, "b1"));
        q.done(2);
        // a2 stays ineligible until a1's done() lands
        q.close();
        q.done(1);
        let (c, j) = q.pop().unwrap();
        assert_eq!((c, j), (1, "a2"), "a2 must follow a1's done()");
        q.done(1);
        assert!(q.pop().is_none(), "closed and drained");
    }

    #[test]
    fn push_blocks_at_capacity_until_a_pop() {
        let q = Arc::new(JobQueue::new(1));
        q.push(1, 0).unwrap();
        let pushed = Arc::new(AtomicBool::new(false));
        let handle = {
            let (q, pushed) = (q.clone(), pushed.clone());
            std::thread::spawn(move || {
                q.push(1, 1).unwrap();
                pushed.store(true, Ordering::SeqCst);
            })
        };
        std::thread::sleep(Duration::from_millis(60));
        assert!(!pushed.load(Ordering::SeqCst), "push must block at capacity");
        let (_, j) = q.pop().unwrap();
        assert_eq!(j, 0);
        handle.join().unwrap();
        assert!(pushed.load(Ordering::SeqCst));
        assert_eq!(q.depth(), 1);
    }

    #[test]
    fn close_rejects_new_pushes_but_drains_queued() {
        let q = JobQueue::new(4);
        q.push(1, "kept").unwrap();
        q.close();
        assert!(!q.is_open());
        assert!(q.push(1, "rejected").is_err());
        let (_, j) = q.pop().unwrap();
        assert_eq!(j, "kept");
        q.done(1);
        assert!(q.pop().is_none());
        assert!(q.pop().is_none(), "pop stays None after drain");
    }

    #[test]
    fn close_wakes_blocked_consumers() {
        let q = Arc::new(JobQueue::<u8>::new(2));
        let handle = {
            let q = q.clone();
            std::thread::spawn(move || q.pop())
        };
        std::thread::sleep(Duration::from_millis(30));
        q.close();
        assert_eq!(handle.join().unwrap(), None);
    }

    #[test]
    fn drain_completes_even_with_jobs_in_flight_at_close() {
        let q = Arc::new(JobQueue::new(8));
        q.push(1, "first").unwrap();
        q.push(1, "second").unwrap();
        let (_, j) = q.pop().unwrap();
        assert_eq!(j, "first");
        q.close();
        // "second" is queued behind an in-flight lane: a drainer must
        // block until done() releases it, then get it, then see None
        let handle = {
            let q = q.clone();
            std::thread::spawn(move || {
                let got = q.pop();
                if got.is_some() {
                    q.done(1);
                }
                (got, q.pop())
            })
        };
        std::thread::sleep(Duration::from_millis(30));
        q.done(1);
        let (second, end) = handle.join().unwrap();
        assert_eq!(second.map(|(_, j)| j), Some("second"));
        assert!(end.is_none());
    }
}
