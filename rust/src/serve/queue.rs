//! The bounded job queue: backpressure for producers, FIFO-per-client
//! fairness for consumers, graceful drain on shutdown.
//!
//! Jobs live in per-client *lanes* (a `VecDeque` each). Consumers
//! round-robin across lanes, so one client queueing a hundred panels
//! cannot starve another's single fit — and a lane is skipped while one
//! of its jobs is in flight, which serializes each client's work:
//! results stream back in exactly the order that client submitted them
//! (the per-client FIFO the integration suite pins), while different
//! clients still run concurrently across workers.
//!
//! [`JobQueue::push`] blocks while the queue is at capacity — real
//! backpressure: the connection reader stalls, the client's TCP writes
//! stall, and the client slows down, instead of the server buffering
//! unboundedly. [`JobQueue::close`] stops accepting new work but lets
//! consumers drain everything already queued; once empty, every
//! [`JobQueue::pop`] returns `None` and the workers exit.
//!
//! [`JobQueue::take_group`] is the **fusion window**: after popping a
//! leader job, a worker may gather peer jobs that match a predicate
//! (same shape / engine / options, decided by the worker) to drive
//! through one batched session. Collection is *prefix-only* per lane —
//! a lane's head must match for anything to be taken from it, and takes
//! stop at the first non-matching job — so a client's results can never
//! be reordered by fusion: every fused job precedes every left-behind
//! job of its lane. Tapped lanes are marked in flight exactly like a
//! popped lane (the worker owes one [`JobQueue::done`] per distinct
//! client in the group), and the window waits at most until its
//! deadline for stragglers, returning early once `want` jobs are in
//! hand.

use crate::util::{Error, Result};
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// One client's pending jobs.
struct Lane<T> {
    client: u64,
    jobs: VecDeque<T>,
    /// A popped job from this lane has not been marked done yet; the
    /// lane is ineligible until [`JobQueue::done`] is called, which is
    /// what makes per-client execution (and thus result order) FIFO.
    in_flight: bool,
}

struct State<T> {
    lanes: Vec<Lane<T>>,
    /// Round-robin start position for the next pop.
    cursor: usize,
    /// Queued (not yet popped) jobs across all lanes.
    len: usize,
    open: bool,
}

/// Bounded multi-producer multi-consumer queue with per-client lanes
/// (see module docs).
pub struct JobQueue<T> {
    state: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

impl<T> JobQueue<T> {
    /// `capacity` is the total queued-job bound across all clients
    /// (must be ≥ 1).
    pub fn new(capacity: usize) -> JobQueue<T> {
        assert!(capacity >= 1, "queue capacity must be ≥ 1");
        JobQueue {
            state: Mutex::new(State { lanes: Vec::new(), cursor: 0, len: 0, open: true }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        }
    }

    /// Enqueue a job for `client`, blocking while the queue is full
    /// (backpressure). Errors once the queue has been closed.
    pub fn push(&self, client: u64, job: T) -> Result<()> {
        let mut s = self.state.lock().expect("job queue");
        while s.open && s.len >= self.capacity {
            s = self.not_full.wait(s).expect("job queue");
        }
        if !s.open {
            return Err(Error::InvalidArgument(
                "job queue is shut down: request rejected".into(),
            ));
        }
        match s.lanes.iter_mut().find(|l| l.client == client) {
            Some(lane) => lane.jobs.push_back(job),
            None => s.lanes.push(Lane {
                client,
                jobs: VecDeque::from([job]),
                in_flight: false,
            }),
        }
        s.len += 1;
        // notify_all: a popper and a fusion-window collector may both be
        // waiting, and waking only one could strand the other while an
        // eligible job sits queued
        self.not_empty.notify_all();
        Ok(())
    }

    /// Dequeue the next job, blocking while nothing is eligible. Lanes
    /// are visited round-robin; a lane with an in-flight job is skipped.
    /// Returns `None` only after [`close`](JobQueue::close) once every
    /// queued job has been handed out.
    pub fn pop(&self) -> Option<(u64, T)> {
        let mut s = self.state.lock().expect("job queue");
        loop {
            let nl = s.lanes.len();
            for k in 0..nl {
                let li = (s.cursor + k) % nl;
                let lane = &mut s.lanes[li];
                if !lane.in_flight && !lane.jobs.is_empty() {
                    let job = lane.jobs.pop_front().expect("non-empty lane");
                    let client = lane.client;
                    lane.in_flight = true;
                    s.cursor = (li + 1) % nl;
                    s.len -= 1;
                    self.not_full.notify_one();
                    return Some((client, job));
                }
            }
            if !s.open && s.len == 0 {
                return None;
            }
            s = self.not_empty.wait(s).expect("job queue");
        }
    }

    /// Gather up to `want` additional jobs to fuse with an already-popped
    /// leader job (see module docs). Takes matching jobs from the head of
    /// the leader's own lane and from the head of any lane with no job in
    /// flight — never past the first non-matching job of a lane, so
    /// per-client result order is preserved by construction. Waits until
    /// `deadline` for the group to fill, returning early once `want`
    /// jobs are collected or the queue closes. Every lane taken from is
    /// marked in flight; the caller owes one [`done`](JobQueue::done)
    /// per distinct client across the leader and the returned peers.
    pub fn take_group<F: Fn(&T) -> bool>(
        &self,
        leader: u64,
        want: usize,
        deadline: Instant,
        matches: F,
    ) -> Vec<(u64, T)> {
        let mut got: Vec<(u64, T)> = Vec::new();
        if want == 0 {
            return got;
        }
        let mut s = self.state.lock().expect("job queue");
        loop {
            for li in 0..s.lanes.len() {
                if got.len() >= want {
                    break;
                }
                let lane = &mut s.lanes[li];
                // the leader's own lane is in flight *for this worker*;
                // any other in-flight lane belongs to a different worker
                // and must not be tapped
                if lane.client != leader && lane.in_flight {
                    continue;
                }
                let mut took = 0usize;
                while got.len() < want && lane.jobs.front().is_some_and(&matches) {
                    got.push((lane.client, lane.jobs.pop_front().expect("matched head")));
                    took += 1;
                }
                if took > 0 {
                    lane.in_flight = true;
                    s.len -= took;
                    for _ in 0..took {
                        self.not_full.notify_one();
                    }
                }
            }
            if got.len() >= want || !s.open {
                return got;
            }
            let now = Instant::now();
            if now >= deadline {
                return got;
            }
            let (guard, _) = self
                .not_empty
                .wait_timeout(s, deadline - now)
                .expect("job queue");
            s = guard;
        }
    }

    /// Mark `client`'s in-flight job finished, making its next queued
    /// job eligible. Workers must call this after completing (or
    /// skipping) every popped job.
    pub fn done(&self, client: u64) {
        let mut s = self.state.lock().expect("job queue");
        if let Some(pos) = s.lanes.iter().position(|l| l.client == client) {
            s.lanes[pos].in_flight = false;
            if s.lanes[pos].jobs.is_empty() {
                // drop the empty lane so the round-robin set stays the
                // set of clients with pending work
                s.lanes.remove(pos);
                if s.cursor > pos {
                    s.cursor -= 1;
                }
                let nl = s.lanes.len();
                s.cursor = if nl == 0 { 0 } else { s.cursor % nl };
            }
        }
        // a lane may have just become eligible: wake all waiters (pops
        // blocked on in-flight lanes, and close() drainers)
        self.not_empty.notify_all();
    }

    /// Stop accepting new jobs; queued jobs still drain. Idempotent.
    pub fn close(&self) {
        {
            let mut s = self.state.lock().expect("job queue");
            s.open = false;
        }
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Jobs queued and not yet handed to a worker.
    pub fn depth(&self) -> usize {
        self.state.lock().expect("job queue").len
    }

    pub fn is_open(&self) -> bool {
        self.state.lock().expect("job queue").open
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn single_client_is_fifo() {
        let q = JobQueue::new(8);
        for j in 0..5 {
            q.push(1, j).unwrap();
        }
        for j in 0..5 {
            let (c, got) = q.pop().unwrap();
            assert_eq!((c, got), (1, j));
            q.done(1);
        }
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn lanes_round_robin_across_clients() {
        let q = JobQueue::new(16);
        // client 1 floods, client 2 submits one job afterwards
        for j in 0..4 {
            q.push(1, (1, j)).unwrap();
        }
        q.push(2, (2, 0)).unwrap();
        let (c1, _) = q.pop().unwrap();
        q.done(c1);
        let (c2, _) = q.pop().unwrap();
        q.done(c2);
        // both clients must have been served within the first two pops
        assert_ne!(c1, c2, "round-robin must alternate clients, got {c1} then {c2}");
    }

    #[test]
    fn in_flight_lane_is_skipped_until_done() {
        let q = JobQueue::new(8);
        q.push(1, "a1").unwrap();
        q.push(1, "a2").unwrap();
        q.push(2, "b1").unwrap();
        let (c, j) = q.pop().unwrap();
        assert_eq!((c, j), (1, "a1"));
        // client 1 has a job in flight: the next pop must serve client 2
        let (c, j) = q.pop().unwrap();
        assert_eq!((c, j), (2, "b1"));
        q.done(2);
        // a2 stays ineligible until a1's done() lands
        q.close();
        q.done(1);
        let (c, j) = q.pop().unwrap();
        assert_eq!((c, j), (1, "a2"), "a2 must follow a1's done()");
        q.done(1);
        assert!(q.pop().is_none(), "closed and drained");
    }

    #[test]
    fn push_blocks_at_capacity_until_a_pop() {
        let q = Arc::new(JobQueue::new(1));
        q.push(1, 0).unwrap();
        let pushed = Arc::new(AtomicBool::new(false));
        let handle = {
            let (q, pushed) = (q.clone(), pushed.clone());
            std::thread::spawn(move || {
                q.push(1, 1).unwrap();
                pushed.store(true, Ordering::SeqCst);
            })
        };
        std::thread::sleep(Duration::from_millis(60));
        assert!(!pushed.load(Ordering::SeqCst), "push must block at capacity");
        let (_, j) = q.pop().unwrap();
        assert_eq!(j, 0);
        handle.join().unwrap();
        assert!(pushed.load(Ordering::SeqCst));
        assert_eq!(q.depth(), 1);
    }

    #[test]
    fn close_rejects_new_pushes_but_drains_queued() {
        let q = JobQueue::new(4);
        q.push(1, "kept").unwrap();
        q.close();
        assert!(!q.is_open());
        assert!(q.push(1, "rejected").is_err());
        let (_, j) = q.pop().unwrap();
        assert_eq!(j, "kept");
        q.done(1);
        assert!(q.pop().is_none());
        assert!(q.pop().is_none(), "pop stays None after drain");
    }

    #[test]
    fn close_wakes_blocked_consumers() {
        let q = Arc::new(JobQueue::<u8>::new(2));
        let handle = {
            let q = q.clone();
            std::thread::spawn(move || q.pop())
        };
        std::thread::sleep(Duration::from_millis(30));
        q.close();
        assert_eq!(handle.join().unwrap(), None);
    }

    #[test]
    fn take_group_is_prefix_only_per_lane() {
        let q = JobQueue::new(16);
        // own lane: fusable, fusable, NOT fusable, fusable — the window
        // must stop at the first non-match and leave the tail queued
        for j in [10, 11, 99, 12] {
            q.push(1, j).unwrap();
        }
        let (c, j) = q.pop().unwrap();
        assert_eq!((c, j), (1, 10));
        let got = q.take_group(1, 4, Instant::now(), |&j| j < 50);
        assert_eq!(got, vec![(1, 11)], "must stop at the non-matching head");
        assert_eq!(q.depth(), 2, "99 and 12 stay queued in order");
        q.done(1);
        let (_, j) = q.pop().unwrap();
        assert_eq!(j, 99, "lane order preserved after fusion");
    }

    #[test]
    fn take_group_taps_peer_lanes_and_marks_them_in_flight() {
        let q = JobQueue::new(16);
        q.push(1, 10).unwrap();
        q.push(2, 20).unwrap();
        q.push(2, 21).unwrap();
        q.push(3, 99).unwrap(); // does not match
        let (c, _) = q.pop().unwrap();
        assert_eq!(c, 1);
        let got = q.take_group(1, 8, Instant::now(), |&j| j < 50);
        assert_eq!(got, vec![(2, 20), (2, 21)]);
        // client 2's lane is now in flight: the next pop must serve 3
        let (c, j) = q.pop().unwrap();
        assert_eq!((c, j), (3, 99));
        q.done(1);
        q.done(2);
        q.done(3);
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn take_group_waits_for_stragglers_until_deadline() {
        let q = Arc::new(JobQueue::new(8));
        q.push(1, 10).unwrap();
        let (c, _) = q.pop().unwrap();
        assert_eq!(c, 1);
        let pusher = {
            let q = q.clone();
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(40));
                q.push(2, 20).unwrap();
            })
        };
        let got = q.take_group(1, 2, Instant::now() + Duration::from_millis(400), |&j| j < 50);
        pusher.join().unwrap();
        assert_eq!(got, vec![(2, 20)], "a straggler inside the window must be fused");
        q.done(1);
        q.done(2);
    }

    #[test]
    fn take_group_returns_partial_group_at_deadline() {
        let q = JobQueue::<u32>::new(8);
        q.push(1, 10).unwrap();
        let (c, _) = q.pop().unwrap();
        assert_eq!(c, 1);
        let t0 = Instant::now();
        let got = q.take_group(1, 4, t0 + Duration::from_millis(30), |_| true);
        assert!(got.is_empty(), "no peers arrived: empty group");
        assert!(t0.elapsed() >= Duration::from_millis(30), "must wait out the window");
        q.done(1);
    }

    #[test]
    fn drain_completes_even_with_jobs_in_flight_at_close() {
        let q = Arc::new(JobQueue::new(8));
        q.push(1, "first").unwrap();
        q.push(1, "second").unwrap();
        let (_, j) = q.pop().unwrap();
        assert_eq!(j, "first");
        q.close();
        // "second" is queued behind an in-flight lane: a drainer must
        // block until done() releases it, then get it, then see None
        let handle = {
            let q = q.clone();
            std::thread::spawn(move || {
                let got = q.pop();
                if got.is_some() {
                    q.done(1);
                }
                (got, q.pop())
            })
        };
        std::thread::sleep(Duration::from_millis(30));
        q.done(1);
        let (second, end) = handle.join().unwrap();
        assert_eq!(second.map(|(_, j)| j), Some("second"));
        assert!(end.is_none());
    }
}
