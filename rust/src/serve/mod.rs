//! `serve` — the resident causal-discovery service: a JSON-lines-over-TCP
//! front on the ordering/bootstrap/VarLiNGAM machinery, turning the
//! one-shot CLI repo into a long-lived process that keeps workers hot and
//! reuses work across requests.
//!
//! # Why a service
//!
//! Every other entry point pays full engine/session construction per fit
//! and serves exactly one caller. The ROADMAP's north star — heavy
//! traffic, batching, caching — starts here, with the two reuse levers
//! ParaLiNGAM's scheduler identifies applied across *requests* instead of
//! within one fit: parked session workspaces (hot workers, no per-request
//! allocation/build for repeated shapes) and a content-addressed result
//! cache (repeated panels answered without any computation at all).
//!
//! # Architecture — three tiers
//!
//! The service is one job core behind three interchangeable fronts:
//!
//! ```text
//!            TCP front (JSON lines)      HTTP front (http.rs)
//! client ------------+                 client --POST /fit--+
//!                    |                     (SSE progress)  |
//!                    v                                     v
//!              +-----------------[ Backend ]----------------+
//!              |  connection reader --> bounded JobQueue --> N workers
//!              |   | parse frames         (per-client lanes,  | parked
//!              |   | cache short-circuit   backpressure)      | sessions
//!              |   <---------------- shared line sink <-------+
//!              |                 ResultCache (+ disk segment, cache.rs)
//!              +--------------------------------------------+
//!                                    ^
//!      shard supervisor (shard.rs):  | loopback TCP, frames relayed
//!      front listener --> routes by panel hash --> N child *processes*
//!                         (crash isolation; restart with backoff)
//! ```
//!
//! Every front normalizes onto the [`Backend`] trait, and every child
//! process in a sharded fleet is just this same server again — so the
//! protocol, queue, cache and workers are written once. The tiers
//! compose: a supervisor's shards each persist their slice of the
//! result cache when `--cache-dir` is set, and the supervisor's own
//! front can be TCP, HTTP, or both.
//!
//! - [`protocol`] — the newline-delimited JSON frames (requests: `fit`,
//!   `bootstrap`, `varlingam`, `watch`/`frame`/`end`, `status`,
//!   `metrics`, `cancel`, `shutdown`; responses: `accepted` →
//!   `progress`/`adjacency`… → one terminal
//!   `result`/`error`/`canceled`), with the total, never-panicking
//!   parser. See its docs for the frame grammar with examples.
//! - [`queue`] — the bounded job queue: producers block at capacity
//!   (real backpressure down the TCP connection), consumers round-robin
//!   per-client lanes, each client's jobs run strictly FIFO, shutdown
//!   drains. Its `take_group` is the **fusion window**: a worker that
//!   pops a batchable fit may gather same-shape peers (prefix-only per
//!   lane, so fusion can never reorder a client's results) for up to
//!   [`ServeConfig::fuse_wait_ms`] or until
//!   [`ServeConfig::max_batch`] jobs are in hand.
//! - [`worker`] — worker threads owning parked [`IncrementalSession`]
//!   workspaces keyed by shape + engine config, honoring per-request
//!   `exact`/`pruned` strategy and worker counts, streaming per-step
//!   ordering and per-resample bootstrap progress, checking cancel flags
//!   at step boundaries. `partition[:B]` requests are routed through the
//!   plan layer ([`crate::lingam::partition`]) with blocks-formed /
//!   boundary-pair counters booked into [`ServeMetrics`]. Fused groups
//!   of same-shape fits run through one
//!   [`BatchedSession`](crate::lingam::BatchedSession) — one
//!   standardize pass and one sweep dispatch per step for the whole
//!   group, bitwise the results each job would get alone, cancel still
//!   honored per job at step boundaries, singletons on the existing
//!   per-job path. Members answered by the submit-time cache while
//!   their peers wait in the window leave no ghost slot: the group is
//!   re-filled before dispatch. Fusion rates are observable as the
//!   `batch` object of the `metrics` frame (`batches_dispatched`,
//!   `jobs_fused`, mean occupancy, window wait).
//! - [`cache`] — the panel-hash LRU: 128-bit FNV over panel bits +
//!   canonical engine spec + options, hit/miss/eviction counters.
//!
//! Progress streams because the ordering subsystem already has the right
//! seam: the [`OrderingSession`](crate::lingam::OrderingSession)
//! lifecycle exposes every search step, so the serve driver is
//! `DirectLingam::fit`'s loop with frames between steps — same math,
//! same results (pinned by the integration suite against direct fits).
//!
//! # Watch streams — the long-lived job class
//!
//! A `watch` subscription ([`crate::lingam::streaming`]) breaks the
//! one-request/one-result shape every other job has, so its routing is
//! worth spelling out. The subscription itself travels the normal path:
//! `submit` → `accepted` → queue → worker, which keeps admission
//! control (backpressure, per-client FIFO, cancel registration) uniform.
//! What differs is everything after the pop:
//!
//! - **Sample routing.** At submit time the connection registers an
//!   in-process channel under `(client, id)` in the [`Shared`] watch
//!   registry — *before* the queue push, so `frame` requests arriving
//!   while the subscription still waits in the queue buffer instead of
//!   erroring. The connection reader forwards each `frame`/`end`
//!   request into that channel ([`Backend::watch_feed`]); the worker
//!   drains it, ingests rows into the sliding window, and answers each
//!   full-window frame with an `adjacency` frame on the job's sink.
//! - **Lifetime.** The stream ends on `end` (terminal `result` carrying
//!   the `watch_summary`), on `cancel` (terminal `canceled`), when the
//!   client's connection drops (sender side of the channel is pruned;
//!   the worker observes the disconnect and finishes silently), or on
//!   server shutdown — the worker polls the queue's open flag and
//!   drains gracefully with the same terminal summary, so `watch`
//!   participates in the existing drain contract.
//! - **Scheduling.** A live stream *occupies its worker and its
//!   client's queue lane* until it ends — by design: the lane keeps a
//!   client's frames strictly ordered, and a pinned worker keeps the
//!   window's caches hot. Watch jobs are structurally excluded from the
//!   `take_group` fusion window (fusion only ever matches plain `fit`
//!   jobs) and from the result cache (a stream is stateful; there is
//!   nothing cacheable). Size worker counts accordingly: streams are
//!   cheap per frame but each holds one worker slot while live
//!   (`watch_streams` in the metrics frame is the live-stream gauge).
//!
//! # Observability
//!
//! The serve tier is instrumented end to end by the [`crate::obs`]
//! layer; everything below is served by both fronts (TCP `trace` /
//! `metrics` requests, HTTP `GET /trace/<id>` / `GET /metrics`).
//!
//! **Trace-id lifecycle.** [`Backend::submit`] mints a 128-bit trace id
//! per job ([`crate::obs::trace::TraceBuilder`]) and threads it through
//! [`protocol::JobSpec::trace`]. Every stage the job crosses records a
//! typed span aggregate against it: `cache_probe` (submit-time lookup),
//! `queue_wait` (pop − submit), `fuse_wait` (fusion window),
//! `session_acquire` (pool checkout / session build), `order_step`
//! (one aggregate over all d−1 search steps), `regression`,
//! `frame_flush` (progress-frame writes), `stream` (watch ingest). At
//! the terminal frame the builder closes (an `other` filler span
//! absorbs unattributed time, so spans always sum to the job's total),
//! the record lands in a bounded in-memory ring
//! ([`crate::obs::trace::TraceStore`], capacity
//! [`TRACE_CAPACITY`]), and `result` frames carry the breakdown
//! inline as a compact `"timing"` object:
//!
//! ```json
//! {"id":"a1","event":"result","cached":false,"elapsed_ms":12.5,
//!  "timing":{"trace":"3f2a…32 hex…","total_ms":12.6,"spans":[
//!    {"span":"queue_wait","start_ms":0.0,"ms":0.4,"count":1},
//!    {"span":"order_step","start_ms":0.9,"ms":10.8,"count":31},…]},
//!  "data":{…}}
//! ```
//!
//! `{"cmd":"trace","target":"<trace-or-job-id>"}` (or
//! `GET /trace/<id>`) replays the same spans later; through a shard
//! fleet the supervisor fans the lookup out to every child.
//!
//! **Metric names.** `GET /metrics?format=prometheus` (and the same
//! query on the TCP `metrics` frame's JSON twin) renders, in Prometheus
//! text-exposition 0.0.4:
//!
//! | name | type | meaning |
//! |---|---|---|
//! | `alingam_jobs_submitted_total` … `_completed_total`, `_failed_total`, `_canceled_total` | counter | job terminals |
//! | `alingam_cache_short_circuits_total` | counter | jobs answered at submit from the cache |
//! | `alingam_queue_depth`, `alingam_in_flight`, `alingam_workers` | gauge | scheduler state |
//! | `alingam_uptime_seconds`, `alingam_start_time_seconds` | gauge | process lifetime (start is unix epoch) |
//! | `alingam_busy_seconds_total` | counter | summed per-job wall clock |
//! | `alingam_cache_hits_total`, `_misses_total`, `_evictions_total`, `_disk_hits_total` | counter | cache traffic |
//! | `alingam_cache_eviction_age_seconds_total` | counter | summed in-memory age at eviction |
//! | `alingam_cache_entries`, `alingam_cache_capacity`, `alingam_cache_recovered_entries` | gauge | cache occupancy |
//! | `alingam_sweep_pairs_total`, `_visited_total`, `_skipped_total` | counter | ordering sweep work |
//! | `alingam_partition_blocks_formed_total`, `_boundary_pairs_total` | counter | partitioned-plan work |
//! | `alingam_batches_dispatched_total`, `alingam_jobs_fused_total`, `alingam_fuse_wait_seconds_total` | counter | fusion window |
//! | `alingam_watch_streams` | gauge | live watch subscriptions |
//! | `alingam_watch_frames_ingested_total`, `_refits_incremental_total`, `_refits_full_total`, `_resyncs_total` | counter | watch traffic |
//! | `alingam_job_latency_seconds`, `alingam_queue_wait_seconds`, `alingam_step_seconds`, `alingam_watch_frame_seconds` | summary | latency histograms (p50/p95/p99 + `_sum`/`_count`, companion `_max` gauge) |
//! | `alingam_shards`, `alingam_shards_live`, `alingam_shard_restarts_total` | gauge/counter | fleet tier only |
//!
//! A shard supervisor serves the same exposition with counters summed
//! and histograms snapshot-merged across children (bucketing is
//! deterministic, so the merge is exact at bucket resolution).
//!
//! **Log records.** `--log-level`/`--log-json` configure the
//! [`crate::obs::log`] logger (see its docs for the record schema);
//! serve-stack events (`server_started`, `job_completed`, `job_failed`,
//! `job_canceled`, `shard_spawned`, `shard_exit`, …) carry the trace id
//! so a log line joins against `GET /trace/<id>` and the metrics it
//! moved. Shard children inherit the supervisor's log flags; their
//! stderr is currently discarded by the supervisor (a documented
//! limitation — point children at a collector via their own invocation
//! to keep their records).
//!
//! The `alingam serve` and `alingam client` subcommands wrap this module
//! on the CLI; `Server::start` is the embeddable entry point the
//! integration tests drive.
//!
//! [`IncrementalSession`]: crate::lingam::IncrementalSession

pub mod cache;
pub mod http;
pub mod protocol;
pub mod queue;
pub mod shard;
pub mod worker;

pub use self::cache::{CacheStats, ResultCache};
pub use self::queue::JobQueue;

use crate::coordinator::{Engine, EngineChoice};
use crate::lingam::SweepCounters;
use crate::obs::trace::{SpanKind, TraceBuilder, TraceStore};
use crate::obs::{hist, log, PromText};
use crate::runtime::XlaEngine;
use crate::util::table::{json_escape, json_f64};
use crate::util::Result;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address; port 0 picks a free port (read it back from
    /// [`Server::local_addr`]).
    pub addr: String,
    /// Worker threads (0 ⇒ one per core, capped at 4 — each worker may
    /// itself run a multi-threaded engine, and
    /// [`EngineChoice::resolve_workers`] divides the cores between
    /// them).
    pub workers: usize,
    /// Bounded queue capacity: producers block past this
    /// (backpressure).
    pub queue_capacity: usize,
    /// Result-cache capacity in entries (0 disables caching).
    pub cache_entries: usize,
    /// Fusion window: how long a batchable fit may wait for same-shape
    /// peers before running, in milliseconds. 0 keeps fusion
    /// opportunistic — only jobs already queued when the leader pops are
    /// fused, and no latency is ever added.
    pub fuse_wait_ms: u64,
    /// Most jobs one batched session may drive (≥ 2 enables fusion; the
    /// leader counts toward the limit).
    pub max_batch: usize,
    /// Optional second listener speaking HTTP/1.1 + SSE (see
    /// [`http`]); `None` disables the HTTP front.
    pub http_addr: Option<String>,
    /// Optional directory for the disk-persistent result cache (see
    /// [`cache`]); `None` keeps the cache memory-only.
    pub cache_dir: Option<PathBuf>,
    /// Logger verbosity (`error|warn|info|debug`; see
    /// [`crate::obs::log`]). The embedded default is `warn` so tests
    /// and library embedders stay quiet; the CLI default is `info`.
    pub log_level: String,
    /// Emit log records as JSON objects instead of `key=value` text.
    pub log_json: bool,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: 2,
            queue_capacity: 64,
            cache_entries: 32,
            fuse_wait_ms: 0,
            max_batch: 8,
            http_addr: None,
            cache_dir: None,
            log_level: "warn".to_string(),
            log_json: false,
        }
    }
}

/// Service-level counters, exposed through the `metrics` request (cache
/// counters live on the cache itself; sweep totals are summed from every
/// fit session's [`SweepCounters`]).
#[derive(Default)]
pub struct ServeMetrics {
    pub(crate) jobs_submitted: AtomicU64,
    pub(crate) jobs_completed: AtomicU64,
    pub(crate) jobs_failed: AtomicU64,
    pub(crate) jobs_canceled: AtomicU64,
    /// Results answered at submit time straight from the cache (no job
    /// queued or executed).
    pub(crate) cache_short_circuits: AtomicU64,
    pub(crate) in_flight: AtomicU64,
    /// Total per-job wall-clock, milliseconds.
    pub(crate) busy_ms_total: AtomicU64,
    pub(crate) sweep_pairs_total: AtomicU64,
    pub(crate) sweep_pairs_visited: AtomicU64,
    pub(crate) sweep_pairs_skipped: AtomicU64,
    /// Column blocks formed by partitioned (`partition[:B]`) fits.
    pub(crate) blocks_formed: AtomicU64,
    /// Cross-block boundary pairs partitioned fits visited.
    pub(crate) boundary_pairs: AtomicU64,
    /// Fused groups (≥ 2 jobs) driven through one batched session.
    pub(crate) batches_dispatched: AtomicU64,
    /// Jobs that ran inside a fused group (the per-batch occupancy is
    /// `jobs_fused / batches_dispatched`).
    pub(crate) jobs_fused: AtomicU64,
    /// Total milliseconds batch leaders spent in the fusion window.
    pub(crate) fuse_wait_ms_total: AtomicU64,
    /// Live `watch` subscriptions (gauge: incremented when a stream
    /// starts running, decremented at its terminal frame).
    pub(crate) watch_streams: AtomicU64,
    /// Samples ingested across all watch streams.
    pub(crate) frames_ingested: AtomicU64,
    /// Watch frames answered by the held-order moment-space fast path.
    pub(crate) refits_incremental: AtomicU64,
    /// Watch frames that re-ran the full ordering sweep.
    pub(crate) refits_full: AtomicU64,
    /// Sliding-window moment resyncs across all watch streams.
    pub(crate) resyncs: AtomicU64,
    /// Submit-to-terminal latency of every job (cached short-circuits
    /// included — they are real client-observed latencies).
    pub(crate) hist_job_latency: hist::Histogram,
    /// Submit-to-pop wait (leaders at the queue pop, members at the
    /// fusion-window gather).
    pub(crate) hist_queue_wait: hist::Histogram,
    /// Per-search-step ordering latency across all fit paths.
    pub(crate) hist_step: hist::Histogram,
    /// Watch-frame ingest latency (one observation per ingested row).
    pub(crate) hist_watch_frame: hist::Histogram,
}

impl ServeMetrics {
    pub(crate) fn add_sweep(&self, c: &SweepCounters) {
        self.sweep_pairs_total.fetch_add(c.pairs_total, Ordering::Relaxed);
        self.sweep_pairs_visited.fetch_add(c.pairs_visited, Ordering::Relaxed);
        self.sweep_pairs_skipped.fetch_add(c.pairs_skipped, Ordering::Relaxed);
    }

    pub(crate) fn add_partition(&self, blocks: u64, boundary: u64) {
        self.blocks_formed.fetch_add(blocks, Ordering::Relaxed);
        self.boundary_pairs.fetch_add(boundary, Ordering::Relaxed);
    }

    pub(crate) fn add_batch(&self, jobs: u64, wait_ms: u64) {
        self.batches_dispatched.fetch_add(1, Ordering::Relaxed);
        self.jobs_fused.fetch_add(jobs, Ordering::Relaxed);
        self.fuse_wait_ms_total.fetch_add(wait_ms, Ordering::Relaxed);
    }
}

/// One message routed from a connection reader into a live watch
/// stream's worker.
#[derive(Clone, Debug)]
pub(crate) enum WatchInput {
    /// A streamed sample (`frame` request).
    Row(Vec<f64>),
    /// Graceful end of stream (`end` request).
    End,
}

/// Registry of live watch streams: `(client, id)` → the sender half of
/// the worker's input channel. Registered at submit time (before the
/// queue push, so early frames buffer), pruned when a send observes the
/// worker gone or when the client detaches.
#[derive(Default)]
pub(crate) struct WatchRegistry {
    inner: Mutex<HashMap<(u64, String), std::sync::mpsc::Sender<WatchInput>>>,
}

impl WatchRegistry {
    pub(crate) fn register(
        &self,
        client: u64,
        id: &str,
        tx: std::sync::mpsc::Sender<WatchInput>,
    ) {
        self.inner.lock().expect("watch registry").insert((client, id.to_string()), tx);
    }

    /// Forward one input; `false` when no live stream matches (never
    /// registered, already ended, or the worker hung up).
    pub(crate) fn feed(&self, client: u64, id: &str, input: WatchInput) -> bool {
        let mut inner = self.inner.lock().expect("watch registry");
        let key = (client, id.to_string());
        match inner.get(&key) {
            None => false,
            Some(tx) => {
                if tx.send(input).is_ok() {
                    true
                } else {
                    // the worker dropped its receiver: the stream ended
                    inner.remove(&key);
                    false
                }
            }
        }
    }

    /// Drop every stream belonging to a detached client; the workers
    /// observe the hangup on their next receive and finish silently.
    pub(crate) fn drop_client(&self, client: u64) {
        self.inner.lock().expect("watch registry").retain(|(c, _), _| *c != client);
    }

    pub(crate) fn remove(&self, client: u64, id: &str) {
        self.inner.lock().expect("watch registry").remove(&(client, id.to_string()));
    }
}

/// Server-wide cancel-flag registry: job id → the live flags of every
/// in-progress job submitted under that id (ids are client-chosen, so
/// duplicates across connections are possible — `cancel` flips them
/// all). Entries are unregistered when their job reaches a terminal
/// frame, so the registry only ever holds live jobs.
#[derive(Default)]
pub(crate) struct CancelRegistry {
    inner: Mutex<HashMap<String, Vec<Arc<AtomicBool>>>>,
}

impl CancelRegistry {
    pub(crate) fn register(&self, id: &str, flag: Arc<AtomicBool>) {
        self.inner.lock().expect("cancel registry").entry(id.to_string()).or_default().push(flag);
    }

    /// Set every live flag registered under `id`; `true` if any existed.
    pub(crate) fn cancel(&self, id: &str) -> bool {
        match self.inner.lock().expect("cancel registry").get(id) {
            Some(flags) => {
                for flag in flags {
                    flag.store(true, Ordering::Relaxed);
                }
                !flags.is_empty()
            }
            None => false,
        }
    }

    /// Drop one specific job's flag (pointer identity), pruning the id's
    /// entry once empty.
    pub(crate) fn unregister(&self, id: &str, flag: &Arc<AtomicBool>) {
        let mut inner = self.inner.lock().expect("cancel registry");
        if let Some(flags) = inner.get_mut(id) {
            flags.retain(|f| !Arc::ptr_eq(f, flag));
            if flags.is_empty() {
                inner.remove(id);
            }
        }
    }

    #[cfg(test)]
    fn len(&self) -> usize {
        self.inner.lock().expect("cancel registry").len()
    }
}

/// Completed-job traces the ring buffer keeps for `trace` lookups.
pub const TRACE_CAPACITY: usize = 256;

/// State shared between the acceptor, the connection readers and the
/// workers.
pub(crate) struct Shared {
    pub(crate) queue: JobQueue<worker::Job>,
    pub(crate) cache: ResultCache,
    pub(crate) metrics: ServeMetrics,
    pub(crate) cancels: CancelRegistry,
    pub(crate) watches: WatchRegistry,
    /// Completed-job trace ring (`trace` requests / `GET /trace/<id>`).
    pub(crate) traces: TraceStore,
    /// Unix epoch ms at [`Server::start`] (the `start_unix_ms` status
    /// field and the `alingam_start_time_seconds` gauge).
    pub(crate) start_unix_ms: u64,
    pub(crate) worker_count: usize,
    /// Fusion-window wait bound, ms (see [`ServeConfig::fuse_wait_ms`]).
    pub(crate) fuse_wait_ms: u64,
    /// Fused-group size bound; ≤ 1 disables fusion entirely.
    pub(crate) max_batch: usize,
    /// Lazily built, shared XLA engine (a device thread + compile cache
    /// is far too expensive to stand up per request).
    xla: Mutex<Option<Arc<XlaEngine>>>,
    started: Instant,
    shutdown: AtomicBool,
    stop_flag: Mutex<bool>,
    stop_cv: Condvar,
    /// Live connections (by client id) so shutdown can sever them; each
    /// connection handler removes its own entry when the client goes
    /// away.
    conns: Mutex<Vec<(u64, TcpStream)>>,
    next_client: AtomicU64,
    /// Worker threads that have not yet exited — lets
    /// [`Server::shutdown_within`] bound the drain wait instead of
    /// joining (possibly forever) on a wedged worker.
    workers_live: AtomicUsize,
}

impl Shared {
    pub(crate) fn xla_engine(&self) -> Result<Arc<XlaEngine>> {
        let mut slot = self.xla.lock().expect("xla engine slot");
        if let Some(engine) = &*slot {
            return Ok(engine.clone());
        }
        let engine = Arc::new(XlaEngine::from_default_artifacts()?);
        *slot = Some(engine.clone());
        Ok(engine)
    }

    /// Per-request engine construction: cheap CPU engines are built
    /// fresh, the XLA engine is shared.
    pub(crate) fn build_engine(&self, choice: EngineChoice) -> Result<Engine> {
        match choice {
            EngineChoice::Xla => Ok(Engine::Xla(self.xla_engine()?)),
            other => Engine::build(other),
        }
    }
}

/// What every front (TCP reader, HTTP handler) needs from whatever sits
/// behind it. Two implementations: [`Shared`] executes jobs in-process;
/// [`shard::Fleet`] relays them to child server processes. The fronts
/// are written once against this trait, which is what makes their
/// payloads byte-identical regardless of the tier behind them.
pub(crate) trait Backend: Send + Sync {
    /// Render a `status` frame.
    fn status_frame(&self, id: Option<&str>) -> String;
    /// Render a `metrics` frame.
    fn metrics_frame(&self, id: Option<&str>) -> String;
    /// Look up a completed job's trace by trace id (32 hex chars) or job
    /// id. Returns the *brace-less* body
    /// (`"trace":"…","job":"…","total_ms":…,"spans":[…]`) so each front
    /// wraps it its own way; `None` when no recorded trace matches.
    fn trace_lookup(&self, target: &str) -> Option<String>;
    /// Render the full Prometheus text exposition (fleet tiers merge
    /// their children's counters and histogram snapshots first).
    fn prometheus_text(&self) -> String;
    /// Flip cancel flags for `target`; `true` if any job was known.
    fn cancel(&self, target: &str) -> bool;
    /// A client asked the whole service to shut down.
    fn request_shutdown(&self);
    /// Submit a job. `raw` is the single-line JSON frame for the job
    /// (relay tiers forward it verbatim); in-process tiers use `spec`.
    /// Every response — `accepted` through the terminal frame — goes to
    /// `sink`.
    fn submit(&self, client: u64, raw: &str, spec: protocol::JobSpec, sink: &worker::Sink);
    /// Register a connection for shutdown severing; returns a client id.
    fn attach(&self, stream: &TcpStream) -> u64;
    /// Remove a finished connection (and any per-client relay state).
    fn detach(&self, client: u64);
    fn shutting_down(&self) -> bool;
    /// Route one `frame`/`end` request into the client's live watch
    /// stream; `false` when no such stream exists. Tiers without
    /// in-process streams (the shard relay) keep this default.
    fn watch_feed(&self, _client: u64, _id: &str, _input: WatchInput) -> bool {
        false
    }
}

impl Backend for Shared {
    fn status_frame(&self, id: Option<&str>) -> String {
        status_frame(id, self)
    }

    fn metrics_frame(&self, id: Option<&str>) -> String {
        metrics_frame(id, self)
    }

    fn trace_lookup(&self, target: &str) -> Option<String> {
        self.traces.get(target).map(|r| r.body_json())
    }

    fn prometheus_text(&self) -> String {
        prometheus_text(self)
    }

    fn cancel(&self, target: &str) -> bool {
        self.cancels.cancel(target)
    }

    fn request_shutdown(&self) {
        let mut stop = self.stop_flag.lock().expect("stop flag");
        *stop = true;
        self.stop_cv.notify_all();
    }

    fn submit(&self, client: u64, _raw: &str, mut spec: protocol::JobSpec, sink: &worker::Sink) {
        self.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        // every job gets its trace context here, cached or not — queue
        // wait is measured from this mint instant
        let trace = Arc::new(TraceBuilder::mint(&spec.id));
        spec.trace = trace.id();
        let is_watch = matches!(spec.kind, protocol::JobKind::Watch { .. });
        // a stream is stateful: never cache-answered, never cached
        if !is_watch && short_circuit(self, &spec, &trace, sink) {
            return;
        }
        let id = spec.id.clone();
        let cancel = Arc::new(AtomicBool::new(false));
        self.cancels.register(&id, cancel.clone());
        // watch subscriptions get their input channel *before* the queue
        // push, so frames arriving while the job still waits in the
        // queue buffer instead of erroring
        let watch_rx = if is_watch {
            let (tx, rx) = std::sync::mpsc::channel();
            self.watches.register(client, &id, tx);
            Some(rx)
        } else {
            None
        };
        // `accepted` goes out before the push: the sink mutex then
        // guarantees it precedes any frame the job itself emits,
        // whatever worker timing does
        sink(&protocol::frame_accepted(&id, self.queue.depth()));
        let job =
            worker::Job { spec, cancel: cancel.clone(), sink: sink.clone(), watch_rx, trace };
        // push blocks at capacity: backpressure reaches the client
        // through its stalled connection
        if let Err(e) = self.queue.push(client, job) {
            self.cancels.unregister(&id, &cancel);
            if is_watch {
                self.watches.remove(client, &id);
            }
            sink(&protocol::frame_error(Some(id.as_str()), &e.to_string()));
        }
    }

    fn attach(&self, stream: &TcpStream) -> u64 {
        let client = self.next_client.fetch_add(1, Ordering::Relaxed);
        if let Ok(clone) = stream.try_clone() {
            self.conns.lock().expect("conn list").push((client, clone));
        }
        client
    }

    fn detach(&self, client: u64) {
        self.conns.lock().expect("conn list").retain(|(c, _)| *c != client);
        // hang up this client's live streams; their workers observe the
        // disconnect and finish
        self.watches.drop_client(client);
    }

    fn shutting_down(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    fn watch_feed(&self, client: u64, id: &str, input: WatchInput) -> bool {
        self.watches.feed(client, id, input)
    }
}

/// A running service: acceptor thread(s) + worker threads around a
/// [`Shared`] core. Create with [`Server::start`], stop with
/// [`Server::shutdown`] (graceful: queued jobs drain first) or
/// [`Server::shutdown_within`] (same, but with a bounded wait).
pub struct Server {
    addr: SocketAddr,
    http_addr: Option<SocketAddr>,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    http_accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Bind, spawn the workers and the acceptor(s), return immediately.
    pub fn start(cfg: ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        let http_listener = match &cfg.http_addr {
            Some(a) => Some(TcpListener::bind(a)?),
            None => None,
        };
        let http_addr = match &http_listener {
            Some(l) => Some(l.local_addr()?),
            None => None,
        };
        let worker_count = if cfg.workers == 0 {
            crate::lingam::parallel::default_workers().min(4)
        } else {
            cfg.workers
        };
        let cache = match &cfg.cache_dir {
            Some(dir) => ResultCache::with_dir(cfg.cache_entries, dir)?,
            None => ResultCache::new(cfg.cache_entries),
        };
        // first-call-wins: an embedder that initialized the logger
        // earlier keeps its configuration
        let level = log::Level::parse(&cfg.log_level).unwrap_or(log::Level::Warn);
        log::init(level, cfg.log_json);
        let start_unix_ms = unix_millis_now();
        let shared = Arc::new(Shared {
            queue: JobQueue::new(cfg.queue_capacity.max(1)),
            cache,
            metrics: ServeMetrics::default(),
            cancels: CancelRegistry::default(),
            watches: WatchRegistry::default(),
            traces: TraceStore::new(TRACE_CAPACITY),
            start_unix_ms,
            worker_count,
            fuse_wait_ms: cfg.fuse_wait_ms,
            max_batch: cfg.max_batch.max(1),
            xla: Mutex::new(None),
            started: Instant::now(),
            shutdown: AtomicBool::new(false),
            stop_flag: Mutex::new(false),
            stop_cv: Condvar::new(),
            conns: Mutex::new(Vec::new()),
            next_client: AtomicU64::new(1),
            workers_live: AtomicUsize::new(worker_count),
        });
        let workers = (0..worker_count)
            .map(|k| {
                let sh = shared.clone();
                thread::Builder::new()
                    .name(format!("serve-worker-{k}"))
                    .spawn(move || {
                        worker::worker_loop(&sh);
                        sh.workers_live.fetch_sub(1, Ordering::SeqCst);
                    })
                    .expect("spawn serve worker")
            })
            .collect();
        let accept = {
            let backend: Arc<dyn Backend> = shared.clone();
            thread::Builder::new()
                .name("serve-accept".to_string())
                .spawn(move || accept_loop(listener, backend, false))
                .expect("spawn serve acceptor")
        };
        let http_accept = http_listener.map(|l| {
            let backend: Arc<dyn Backend> = shared.clone();
            thread::Builder::new()
                .name("serve-http-accept".to_string())
                .spawn(move || accept_loop(l, backend, true))
                .expect("spawn serve http acceptor")
        });
        log::info(
            "server_started",
            &[
                ("addr", &addr.to_string()),
                ("http", &http_addr.map(|a| a.to_string()).unwrap_or_default()),
                ("workers", &worker_count.to_string()),
            ],
        );
        Ok(Server { addr, http_addr, shared, accept: Some(accept), http_accept, workers })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The bound HTTP address, when the HTTP front is enabled.
    pub fn http_local_addr(&self) -> Option<SocketAddr> {
        self.http_addr
    }

    /// Jobs queued and not yet running.
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.depth()
    }

    /// Result-cache counters (tests; clients use the `metrics` frame).
    pub fn cache_stats(&self) -> CacheStats {
        self.shared.cache.stats()
    }

    /// Block until some client sends a `shutdown` frame (the CLI `serve`
    /// command waits here, then calls [`Server::shutdown`]).
    pub fn wait_for_shutdown_request(&self) {
        let mut stop = self.shared.stop_flag.lock().expect("stop flag");
        while !*stop {
            stop = self.shared.stop_cv.wait(stop).expect("stop flag");
        }
    }

    /// Graceful shutdown: stop accepting connections and jobs, let the
    /// workers drain everything already queued (results still stream to
    /// their clients), then sever remaining connections. A worker that
    /// never finishes would wedge this forever — the CLI path uses
    /// [`Server::shutdown_within`] instead.
    pub fn shutdown(self) {
        let _ = self.shutdown_within(Duration::from_secs(600));
    }

    /// [`Server::shutdown`] with a bound on the drain: waits up to
    /// `limit` for the workers to finish the queued jobs, then severs
    /// connections regardless. Returns `true` when the drain completed
    /// cleanly within the limit; on `false` the worker threads are
    /// leaked (they hold no lock anyone else needs) rather than joined,
    /// so the caller can still exit.
    pub fn shutdown_within(mut self, limit: Duration) -> bool {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.queue.close();
        // the acceptors block in accept(): poke them awake
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.http_addr {
            let _ = TcpStream::connect(a);
        }
        if let Some(handle) = self.accept.take() {
            let _ = handle.join();
        }
        if let Some(handle) = self.http_accept.take() {
            let _ = handle.join();
        }
        let deadline = Instant::now() + limit;
        let clean = loop {
            if self.shared.workers_live.load(Ordering::SeqCst) == 0 {
                break true;
            }
            if Instant::now() >= deadline {
                break false;
            }
            thread::sleep(Duration::from_millis(10));
        };
        if clean {
            for handle in self.workers.drain(..) {
                let _ = handle.join();
            }
        }
        // every drained result is written; now unblock the connection
        // readers so their threads exit
        for (_client, conn) in self.shared.conns.lock().expect("conn list").drain(..) {
            let _ = conn.shutdown(std::net::Shutdown::Both);
        }
        clean
    }
}

/// Accept connections for one listener, handing each to the TCP
/// (JSON-lines) or HTTP front against the same [`Backend`].
pub(crate) fn accept_loop(listener: TcpListener, backend: Arc<dyn Backend>, is_http: bool) {
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                if backend.shutting_down() {
                    break;
                }
                let b = backend.clone();
                let name = if is_http { "serve-http-conn" } else { "serve-conn" };
                let _ = thread::Builder::new().name(name.to_string()).spawn(move || {
                    if is_http {
                        http::handle_http(stream, b);
                    } else {
                        handle_connection(stream, b);
                    }
                });
            }
            Err(_) => {
                if backend.shutting_down() {
                    break;
                }
            }
        }
    }
}

/// One JSON-lines connection: read frames line by line, answer control
/// requests inline, submit jobs to the backend. `cancel` targets are
/// looked up server-wide (through the backend), so a second connection
/// (the one-shot `alingam client cancel`) can cancel a job submitted on
/// another.
pub(crate) fn handle_connection(stream: TcpStream, backend: Arc<dyn Backend>) {
    use protocol::Request;
    // bound how long a worker can stall writing results to a client
    // that stopped reading: past this, frames to that client are dropped
    // instead of wedging the worker (and the graceful drain) forever
    let _ = stream.set_write_timeout(Some(Duration::from_secs(30)));
    let client = backend.attach(&stream);
    let reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => {
            backend.detach(client);
            return;
        }
    };
    let out = Mutex::new(stream);
    let sink: worker::Sink = Arc::new(move |line: &str| {
        if let Ok(mut s) = out.lock() {
            let _ = s.write_all(line.as_bytes());
            let _ = s.write_all(b"\n");
        }
    });
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        match protocol::parse_request(&line) {
            Err(e) => sink(&protocol::frame_error(None, &e.to_string())),
            Ok(Request::Status { id }) => sink(&backend.status_frame(id.as_deref())),
            Ok(Request::Metrics { id }) => sink(&backend.metrics_frame(id.as_deref())),
            Ok(Request::Trace { id, target }) => match backend.trace_lookup(&target) {
                Some(body) => {
                    let payload = format!("\"event\":\"trace\",\"found\":true,{body}");
                    sink(&with_id(id.as_deref(), &payload));
                }
                None => {
                    let payload = format!(
                        "\"event\":\"trace\",\"found\":false,\"target\":\"{}\"",
                        json_escape(&target)
                    );
                    sink(&with_id(id.as_deref(), &payload));
                }
            },
            Ok(Request::Cancel { id, target }) => {
                let known = backend.cancel(&target);
                sink(&protocol::frame_ack(id.as_deref(), "cancel", known));
            }
            Ok(Request::Shutdown { id }) => {
                sink(&protocol::frame_ack(id.as_deref(), "shutdown", true));
                backend.request_shutdown();
            }
            Ok(Request::Frame { id, row }) => {
                if !backend.watch_feed(client, &id, WatchInput::Row(row)) {
                    sink(&protocol::frame_error(
                        Some(&id),
                        "no live watch stream with this id on this connection",
                    ));
                }
            }
            Ok(Request::End { id }) => {
                if !backend.watch_feed(client, &id, WatchInput::End) {
                    sink(&protocol::frame_error(
                        Some(&id),
                        "no live watch stream with this id on this connection",
                    ));
                }
            }
            Ok(Request::Job(spec)) => backend.submit(client, &line, spec, &sink),
        }
    }
    // this connection is gone: drop its tracked clone so a long-lived
    // server does not leak one fd per client ever served
    backend.detach(client);
}

/// Submit-time cache short-circuit: a byte-identical inline request
/// replays its cached result frame without queueing a job at all (CSV
/// panels are hashed by the worker after loading instead, so disk reads
/// stay off the connection thread). Returns `true` when the request was
/// answered here.
fn short_circuit(
    shared: &Shared,
    spec: &protocol::JobSpec,
    trace: &TraceBuilder,
    sink: &worker::Sink,
) -> bool {
    let protocol::PanelSource::Inline(panel) = &spec.panel else {
        return false;
    };
    let Ok(choice) = EngineChoice::parse(&spec.engine) else {
        return false;
    };
    let choice = choice.resolve_workers(shared.worker_count);
    let probe = Instant::now();
    let key = worker::cache_key(panel, choice, &spec.kind);
    let hit = shared.cache.get(key);
    trace.record_at(SpanKind::CacheProbe, probe, probe.elapsed());
    match hit {
        Some(hit) => {
            shared.metrics.cache_short_circuits.fetch_add(1, Ordering::Relaxed);
            let rec = trace.finish();
            sink(&protocol::frame_result_traced(
                Some(spec.id.as_str()),
                true,
                0.0,
                &hit,
                Some(&rec.timing_json()),
            ));
            // a short-circuit is still a client-observed job latency
            shared.metrics.hist_job_latency.record_us(rec.total_us.max(1));
            log::info(
                "job_completed",
                &[("job", spec.id.as_str()), ("trace", &rec.trace_hex), ("cached", "true")],
            );
            shared.traces.insert(rec);
            true
        }
        None => false,
    }
}

/// Wall-clock Unix time in milliseconds (0 if the clock is before the
/// epoch) — the `start_unix_ms` both serve tiers stamp at boot.
pub(crate) fn unix_millis_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::SystemTime::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

fn with_id(id: Option<&str>, body: &str) -> String {
    match id {
        Some(id) => format!("{{\"id\":\"{}\",{body}}}", json_escape(id)),
        None => format!("{{{body}}}"),
    }
}

fn status_frame(id: Option<&str>, shared: &Shared) -> String {
    let body = format!(
        "\"event\":\"status\",\"queue_depth\":{},\"in_flight\":{},\"workers\":{},\
         \"uptime_ms\":{},\"start_unix_ms\":{},\"accepting\":{}",
        shared.queue.depth(),
        shared.metrics.in_flight.load(Ordering::Relaxed),
        shared.worker_count,
        shared.started.elapsed().as_millis(),
        shared.start_unix_ms,
        shared.queue.is_open()
    );
    with_id(id, &body)
}

fn metrics_frame(id: Option<&str>, shared: &Shared) -> String {
    let m = &shared.metrics;
    let c = shared.cache.stats();
    let jobs = format!(
        "{{\"submitted\":{},\"completed\":{},\"failed\":{},\"canceled\":{},\
         \"cache_short_circuits\":{}}}",
        m.jobs_submitted.load(Ordering::Relaxed),
        m.jobs_completed.load(Ordering::Relaxed),
        m.jobs_failed.load(Ordering::Relaxed),
        m.jobs_canceled.load(Ordering::Relaxed),
        m.cache_short_circuits.load(Ordering::Relaxed),
    );
    let cache = format!(
        "{{\"hits\":{},\"misses\":{},\"evictions\":{},\"entries\":{},\"capacity\":{},\
         \"hit_rate\":{},\"disk_hits\":{},\"recovered\":{},\"eviction_age_ms_total\":{},\
         \"mean_eviction_age_ms\":{}}}",
        c.hits,
        c.misses,
        c.evictions,
        c.entries,
        c.capacity,
        json_f64(c.hit_rate()),
        c.disk_hits,
        c.recovered,
        c.eviction_age_ms_total,
        json_f64(c.mean_eviction_age_ms()),
    );
    let sweep = format!(
        "{{\"pairs_total\":{},\"pairs_visited\":{},\"pairs_skipped\":{}}}",
        m.sweep_pairs_total.load(Ordering::Relaxed),
        m.sweep_pairs_visited.load(Ordering::Relaxed),
        m.sweep_pairs_skipped.load(Ordering::Relaxed),
    );
    let partition = format!(
        "{{\"blocks_formed\":{},\"boundary_pairs\":{}}}",
        m.blocks_formed.load(Ordering::Relaxed),
        m.boundary_pairs.load(Ordering::Relaxed),
    );
    let dispatched = m.batches_dispatched.load(Ordering::Relaxed);
    let fused = m.jobs_fused.load(Ordering::Relaxed);
    let occupancy = if dispatched == 0 { 0.0 } else { fused as f64 / dispatched as f64 };
    let batch = format!(
        "{{\"batches_dispatched\":{dispatched},\"jobs_fused\":{fused},\
         \"mean_occupancy\":{},\"fuse_wait_ms_total\":{}}}",
        json_f64(occupancy),
        m.fuse_wait_ms_total.load(Ordering::Relaxed),
    );
    let watch = format!(
        "{{\"watch_streams\":{},\"frames_ingested\":{},\"refits_incremental\":{},\
         \"refits_full\":{},\"resyncs\":{}}}",
        m.watch_streams.load(Ordering::Relaxed),
        m.frames_ingested.load(Ordering::Relaxed),
        m.refits_incremental.load(Ordering::Relaxed),
        m.refits_full.load(Ordering::Relaxed),
        m.resyncs.load(Ordering::Relaxed),
    );
    // the histogram snapshots ride along so a shard supervisor can
    // rebuild and merge them (`Snapshot::from_parts` — bucketing is
    // deterministic, so the merge is exact at bucket resolution)
    let obs = format!(
        "{{\"job_latency\":{},\"queue_wait\":{},\"step\":{},\"watch_frame\":{}}}",
        m.hist_job_latency.snapshot().to_json(),
        m.hist_queue_wait.snapshot().to_json(),
        m.hist_step.snapshot().to_json(),
        m.hist_watch_frame.snapshot().to_json(),
    );
    let body = format!(
        "\"event\":\"metrics\",\"workers\":{},\"uptime_ms\":{},\"start_unix_ms\":{},\
         \"queue_depth\":{},\"in_flight\":{},\"busy_ms_total\":{},\"jobs\":{jobs},\
         \"cache\":{cache},\"sweep\":{sweep},\"partition\":{partition},\"batch\":{batch},\
         \"watch\":{watch},\"obs\":{obs}",
        shared.worker_count,
        shared.started.elapsed().as_millis(),
        shared.start_unix_ms,
        shared.queue.depth(),
        m.in_flight.load(Ordering::Relaxed),
        m.busy_ms_total.load(Ordering::Relaxed),
    );
    with_id(id, &body)
}

/// Render the solo-tier Prometheus exposition (the names documented in
/// the module docs; the fleet tier builds its own merged rendering in
/// [`shard`]).
fn prometheus_text(shared: &Shared) -> String {
    let m = &shared.metrics;
    let c = shared.cache.stats();
    let ld = |a: &AtomicU64| a.load(Ordering::Relaxed) as f64;
    let mut p = PromText::new();
    p.single(
        "alingam_jobs_submitted_total",
        "counter",
        "Jobs accepted by submit.",
        ld(&m.jobs_submitted),
    );
    p.single(
        "alingam_jobs_completed_total",
        "counter",
        "Jobs that ended in a result frame.",
        ld(&m.jobs_completed),
    );
    p.single(
        "alingam_jobs_failed_total",
        "counter",
        "Jobs that ended in an error frame.",
        ld(&m.jobs_failed),
    );
    p.single(
        "alingam_jobs_canceled_total",
        "counter",
        "Jobs that ended in a canceled frame.",
        ld(&m.jobs_canceled),
    );
    p.single(
        "alingam_cache_short_circuits_total",
        "counter",
        "Jobs answered at submit time straight from the result cache.",
        ld(&m.cache_short_circuits),
    );
    p.single(
        "alingam_queue_depth",
        "gauge",
        "Jobs queued and not yet running.",
        shared.queue.depth() as f64,
    );
    p.single("alingam_in_flight", "gauge", "Jobs currently executing.", ld(&m.in_flight));
    p.single("alingam_workers", "gauge", "Worker threads.", shared.worker_count as f64);
    p.single(
        "alingam_uptime_seconds",
        "gauge",
        "Seconds since server start (monotonic clock).",
        shared.started.elapsed().as_secs_f64(),
    );
    p.single(
        "alingam_start_time_seconds",
        "gauge",
        "Unix time the server started, in seconds.",
        shared.start_unix_ms as f64 / 1e3,
    );
    p.single(
        "alingam_busy_seconds_total",
        "counter",
        "Summed per-job wall clock, in seconds.",
        ld(&m.busy_ms_total) / 1e3,
    );
    p.single("alingam_cache_hits_total", "counter", "Result-cache hits.", c.hits as f64);
    p.single("alingam_cache_misses_total", "counter", "Result-cache misses.", c.misses as f64);
    p.single(
        "alingam_cache_evictions_total",
        "counter",
        "Result-cache LRU evictions.",
        c.evictions as f64,
    );
    p.single(
        "alingam_cache_disk_hits_total",
        "counter",
        "Results recovered from the disk segment.",
        c.disk_hits as f64,
    );
    p.single(
        "alingam_cache_eviction_age_seconds_total",
        "counter",
        "Summed in-memory age of evicted cache entries, in seconds.",
        c.eviction_age_ms_total as f64 / 1e3,
    );
    p.single("alingam_cache_entries", "gauge", "Live result-cache entries.", c.entries as f64);
    p.single(
        "alingam_cache_capacity",
        "gauge",
        "Result-cache capacity in entries.",
        c.capacity as f64,
    );
    p.single(
        "alingam_cache_recovered_entries",
        "gauge",
        "Entries recovered from the disk segment at startup.",
        c.recovered as f64,
    );
    p.single(
        "alingam_sweep_pairs_total",
        "counter",
        "Candidate pairs across all ordering sweeps.",
        ld(&m.sweep_pairs_total),
    );
    p.single(
        "alingam_sweep_pairs_visited_total",
        "counter",
        "Pairs actually scored.",
        ld(&m.sweep_pairs_visited),
    );
    p.single(
        "alingam_sweep_pairs_skipped_total",
        "counter",
        "Pairs skipped by bound pruning.",
        ld(&m.sweep_pairs_skipped),
    );
    p.single(
        "alingam_partition_blocks_formed_total",
        "counter",
        "Column blocks formed by partitioned fits.",
        ld(&m.blocks_formed),
    );
    p.single(
        "alingam_partition_boundary_pairs_total",
        "counter",
        "Cross-block boundary pairs partitioned fits visited.",
        ld(&m.boundary_pairs),
    );
    p.single(
        "alingam_batches_dispatched_total",
        "counter",
        "Fused groups driven through one batched session.",
        ld(&m.batches_dispatched),
    );
    p.single(
        "alingam_jobs_fused_total",
        "counter",
        "Jobs that ran inside a fused group.",
        ld(&m.jobs_fused),
    );
    p.single(
        "alingam_fuse_wait_seconds_total",
        "counter",
        "Total time batch leaders held the fusion window open, in seconds.",
        ld(&m.fuse_wait_ms_total) / 1e3,
    );
    p.single("alingam_watch_streams", "gauge", "Live watch subscriptions.", ld(&m.watch_streams));
    p.single(
        "alingam_watch_frames_ingested_total",
        "counter",
        "Samples ingested across all watch streams.",
        ld(&m.frames_ingested),
    );
    p.single(
        "alingam_watch_refits_incremental_total",
        "counter",
        "Watch frames answered by the held-order fast path.",
        ld(&m.refits_incremental),
    );
    p.single(
        "alingam_watch_refits_full_total",
        "counter",
        "Watch frames that re-ran the full ordering sweep.",
        ld(&m.refits_full),
    );
    p.single(
        "alingam_watch_resyncs_total",
        "counter",
        "Sliding-window moment resyncs across all watch streams.",
        ld(&m.resyncs),
    );
    p.summary_seconds(
        "alingam_job_latency_seconds",
        "Submit-to-terminal job latency (cached short-circuits included).",
        &m.hist_job_latency.snapshot(),
    );
    p.summary_seconds(
        "alingam_queue_wait_seconds",
        "Submit-to-pop queue wait.",
        &m.hist_queue_wait.snapshot(),
    );
    p.summary_seconds(
        "alingam_step_seconds",
        "Per-search-step ordering latency.",
        &m.hist_step.snapshot(),
    );
    p.summary_seconds(
        "alingam_watch_frame_seconds",
        "Watch-frame ingest latency.",
        &m.hist_watch_frame.snapshot(),
    );
    p.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cancel_registry_flips_all_flags_for_an_id_and_prunes_on_unregister() {
        let reg = CancelRegistry::default();
        assert!(!reg.cancel("missing"), "unknown ids report not-found");
        let a = Arc::new(AtomicBool::new(false));
        let b = Arc::new(AtomicBool::new(false));
        reg.register("job", a.clone());
        reg.register("job", b.clone());
        assert_eq!(reg.len(), 1);
        assert!(reg.cancel("job"));
        assert!(a.load(Ordering::Relaxed) && b.load(Ordering::Relaxed));
        // unregister is by flag identity and prunes empty entries
        reg.unregister("job", &a);
        assert_eq!(reg.len(), 1);
        assert!(reg.cancel("job"), "b is still live");
        reg.unregister("job", &b);
        assert_eq!(reg.len(), 0);
        assert!(!reg.cancel("job"));
        // unregistering something never registered is a no-op
        reg.unregister("job", &a);
    }
}
