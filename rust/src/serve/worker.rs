//! The worker half of the service: N threads, each owning a pool of
//! parked [`IncrementalSession`] workspaces, draining the job queue.
//!
//! This is the PR 2 bootstrap-pool pattern promoted to the process
//! level: a worker that has once fitted an `[n, d]` panel with a given
//! engine configuration keeps that session parked, and the next job with
//! the same shape re-seeds it with [`OrderingSession::reset`] — reusing
//! the standardized-cache / correlation-matrix buffers instead of paying
//! the allocation and build again (hot workers, ParaLiNGAM-style reuse
//! across *requests* rather than resamples). Pools are per-worker-thread
//! owned, so there is no locking on the session path.
//!
//! Engines whose sessions borrow the engine — the sequential baseline's
//! stateless shim and the device-resident XLA session — run one session
//! per job instead; the XLA engine itself (device thread + compile
//! cache) is shared server-wide and built lazily on first use.
//! `partition[:B]` requests are not session-backed at all: they run
//! through the plan layer ([`DirectLingam::fit_plan`] with a
//! [`PartitionedPlan`]), booking blocks-formed and boundary-pair
//! counters into the server metrics alongside the sweep counters.
//!
//! Every job honors its request's `exact`/`pruned` strategy and worker
//! count through [`EngineChoice`] (auto counts are divided across the
//! server's workers by [`EngineChoice::resolve_workers`]), checks its
//! cancel flag at step/resample boundaries, and books its session's
//! [`SweepCounters`](crate::lingam::SweepCounters) into the server
//! metrics.
//!
//! # The fusion window
//!
//! A popped fit whose engine has an incremental workspace (a
//! [`FuseKey`]) opens the queue's fusion window
//! ([`JobQueue::take_group`](super::queue::JobQueue::take_group)):
//! same-shape, same-engine-config peers gathered for up to
//! `fuse_wait_ms` — or until `max_batch` members — run through **one**
//! [`BatchedSession`], paying one standardize pass and one sweep
//! dispatch per lock step for the whole group instead of per job.
//! Fusion is strictly an execution optimization: the batched session is
//! bitwise-parity-pinned against solo fits, each member streams its own
//! progress, honors its own cancel flag at step boundaries (a canceled
//! member drops out of the batch without stalling peers), fills its own
//! cache entry and gets its own terminal frame. Members answered by the
//! result cache (or already canceled) while the window is open leave
//! the group immediately and their slots are refilled — no ghost slots
//! dispatching a batch below `max_batch`. Groups that close with the
//! leader alone fall back to the per-job path above.
//!
//! # Watch streams
//!
//! A `watch` job ([`JobKind::Watch`]) is the long-lived exception to
//! everything above: it parks on this worker thread for its whole
//! lifetime, pulling samples off the channel the reader registered at
//! submit time and driving a [`StreamingLingam`] /
//! [`StreamingVarLingam`] window — full ordering sweeps only on first
//! fill and moment resyncs, held-order coefficient re-estimation per
//! frame in between. Watch jobs are structurally outside the fusion
//! window ([`fuse_key`] only matches fits) and never touch the result
//! cache (a stream has no single answer to replay); they do book the
//! streaming counters (`frames_ingested`, `refits_incremental`,
//! `refits_full`, `resyncs`) and hold the `watch_streams` gauge while
//! live. The loop polls the job's cancel flag and the queue's open
//! state between samples, so `cancel` frames and server drain both
//! terminate a stream promptly even when no samples arrive.

use super::cache::Fnv128;
use super::protocol::{self, JobKind, JobSpec, PanelSource};
use super::{Shared, WatchInput};
use crate::coordinator::{
    bootstrap_direct_observed, bootstrap_partition_observed, BootstrapOpts, EngineChoice,
};
use crate::linalg::Mat;
use crate::lingam::direct::validate_panel;
use crate::lingam::prune::PruneMethod;
use crate::lingam::{
    BatchedSession, DirectLingam, IncrementalSession, LingamFit, OrderingEngine, OrderingSession,
    PartitionSpec, PartitionedPlan, RefitKind, SequentialEngine, StepObserver, StreamingConfig,
    StreamingLingam, StreamingVarLingam, SweepCounters, SweepStrategy, VarLingam,
};
use crate::obs::log;
use crate::obs::trace::{SpanKind, TraceBuilder, TraceRecord};
use crate::util::{Error, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Where a job's response frames go: a connection-owned line writer
/// (tests substitute a collecting closure). Must tolerate a vanished
/// client (writes to a closed socket are silently dropped).
pub type Sink = Arc<dyn Fn(&str) + Send + Sync>;

/// A queued unit of work: the protocol spec plus the runtime attachments
/// the connection handler created for it.
pub struct Job {
    pub spec: JobSpec,
    /// Cooperative cancel flag, checked at step/resample boundaries.
    pub cancel: Arc<AtomicBool>,
    pub sink: Sink,
    /// Watch jobs only: the receiving end of the sample channel the
    /// reader registered in the server's watch registry at submit time.
    /// `None` for every one-shot job kind.
    pub watch_rx: Option<Receiver<WatchInput>>,
    /// The job's trace context, minted at submit (see [`crate::obs`]):
    /// every pipeline stage records its span here, and the terminal
    /// frame carries the closed record as `"timing"`.
    pub trace: Arc<TraceBuilder>,
}

/// Close a job's trace at its terminal frame: book the submit-to-done
/// latency, log the terminal event with the trace id, and park the
/// record for `trace` lookups. Returns the record so `result` frames can
/// embed its `timing_json` (error/cancel terminals drop it).
fn finish_trace(shared: &Shared, job: &Job, event: &str) -> TraceRecord {
    let rec = job.trace.finish();
    shared.metrics.hist_job_latency.record_us(rec.total_us.max(1));
    let fields = [("job", job.spec.id.as_str()), ("trace", rec.trace_hex.as_str())];
    if event == "job_failed" {
        log::warn(event, &fields);
    } else {
        log::info(event, &fields);
    }
    shared.traces.insert(rec.clone());
    rec
}

/// Book the submit-to-pop wait into the job's trace and the queue-wait
/// histogram (called at the queue pop for leaders, at the fusion-window
/// tap for gathered members).
fn record_queue_wait(shared: &Shared, job: &Job) {
    let waited = job.trace.started().elapsed();
    job.trace.record_at(SpanKind::QueueWait, job.trace.started(), waited);
    shared.metrics.hist_queue_wait.record(waited);
}

/// Shape + engine configuration a parked workspace can be reused for.
#[derive(Clone, Copy, Debug, Hash, PartialEq, Eq)]
struct PoolKey {
    n: usize,
    d: usize,
    workers: usize,
    pruned: bool,
}

type SessionPool = HashMap<PoolKey, IncrementalSession>;

/// Parked sessions kept per worker: a workspace is O(n·d) cache plus an
/// O(d²) correlation matrix, so the pool is capped — past this, an
/// arbitrary parked entry is evicted (shape traffic is usually highly
/// repetitive, so any small cap keeps the hot shapes resident).
const MAX_PARKED_SESSIONS: usize = 8;

/// One worker thread: drain the queue until close-and-empty, keeping
/// per-shape parked sessions across jobs. Batchable fits route through
/// the fusion window; everything else runs the per-job path.
pub(super) fn worker_loop(shared: &Shared) {
    let mut pool: SessionPool = HashMap::new();
    while let Some((client, job)) = shared.queue.pop() {
        record_queue_wait(shared, &job);
        match fuse_key(shared.worker_count, &job) {
            Some(key) if shared.max_batch > 1 => run_fused(shared, &mut pool, client, job, key),
            _ => {
                shared.metrics.in_flight.fetch_add(1, Ordering::Relaxed);
                run_job(shared, &mut pool, &job);
                shared.metrics.in_flight.fetch_sub(1, Ordering::Relaxed);
                shared.cancels.unregister(&job.spec.id, &job.cancel);
                shared.queue.done(client);
            }
        }
    }
}

/// The fusion identity of a batchable job: inline `fit` jobs with the
/// same shape and the same resolved engine configuration may share one
/// batched session. `None` for anything else (CSV panels, bootstrap /
/// var jobs, engines without an incremental workspace).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct FuseKey {
    n: usize,
    d: usize,
    choice: EngineChoice,
}

fn fuse_key(worker_count: usize, job: &Job) -> Option<FuseKey> {
    if !matches!(job.spec.kind, JobKind::Fit) {
        return None;
    }
    let PanelSource::Inline(panel) = &job.spec.panel else {
        return None;
    };
    let choice = EngineChoice::parse(&job.spec.engine).ok()?.resolve_workers(worker_count);
    incremental_params(choice)?;
    Some(FuseKey { n: panel.rows(), d: panel.cols(), choice })
}

/// Worker-side cache re-check (the reader's submit-time short circuit
/// can miss: an identical job may complete while this one sits in the
/// queue or the fusion window). Answers and books the job on a hit.
fn answer_from_cache(shared: &Shared, job: &Job) -> bool {
    let PanelSource::Inline(panel) = &job.spec.panel else {
        return false;
    };
    let Ok(choice) = EngineChoice::parse(&job.spec.engine) else {
        return false;
    };
    let choice = choice.resolve_workers(shared.worker_count);
    let probe = Instant::now();
    let hit = shared.cache.get(cache_key(panel, choice, &job.spec.kind));
    job.trace.record_at(SpanKind::CacheProbe, probe, probe.elapsed());
    match hit {
        Some(hit) => {
            shared.metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
            let rec = finish_trace(shared, job, "job_completed");
            (job.sink)(&protocol::frame_result_traced(
                Some(job.spec.id.as_str()),
                true,
                0.0,
                &hit,
                Some(&rec.timing_json()),
            ));
            true
        }
        None => false,
    }
}

/// Drive a batchable leader job through the fusion window: gather
/// same-key peers (bounded by `max_batch` / `fuse_wait_ms`), prune
/// members answered by the cache or already canceled — their freed
/// slots refill from the queue — then dispatch the group through one
/// [`BatchedSession`], or fall back to the per-job path when the window
/// closes with the leader alone. The worker owes the queue one `done`
/// per distinct client it took jobs from, batched or pruned alike.
fn run_fused(shared: &Shared, pool: &mut SessionPool, leader: u64, job: Job, key: FuseKey) {
    let t0 = Instant::now();
    let deadline = t0 + Duration::from_millis(shared.fuse_wait_ms);
    if job.cancel.load(Ordering::Relaxed) {
        shared.metrics.jobs_canceled.fetch_add(1, Ordering::Relaxed);
        finish_trace(shared, &job, "job_canceled");
        (job.sink)(&protocol::frame_canceled(&job.spec.id));
        shared.cancels.unregister(&job.spec.id, &job.cancel);
        shared.queue.done(leader);
        return;
    }
    if answer_from_cache(shared, &job) {
        shared.cancels.unregister(&job.spec.id, &job.cancel);
        shared.queue.done(leader);
        return;
    }
    let mut owed = vec![leader];
    let mut members: Vec<Job> = vec![job];
    // when each member entered the window (leader: when it opened), so
    // the fuse-wait span covers exactly tap → dispatch per job
    let mut taps: Vec<Instant> = vec![t0];
    loop {
        let want = shared.max_batch.saturating_sub(members.len());
        if want == 0 {
            break;
        }
        let peers = shared.queue.take_group(leader, want, deadline, |j| {
            fuse_key(shared.worker_count, j) == Some(key)
        });
        if peers.is_empty() {
            break;
        }
        for (c, j) in peers {
            if !owed.contains(&c) {
                owed.push(c);
            }
            record_queue_wait(shared, &j);
            // ghost-slot fix: members answered before dispatch leave the
            // group immediately, so the next round refills their slots
            // instead of dispatching a batch below `max_batch`
            if j.cancel.load(Ordering::Relaxed) {
                shared.metrics.jobs_canceled.fetch_add(1, Ordering::Relaxed);
                finish_trace(shared, &j, "job_canceled");
                (j.sink)(&protocol::frame_canceled(&j.spec.id));
                shared.cancels.unregister(&j.spec.id, &j.cancel);
            } else if answer_from_cache(shared, &j) {
                shared.cancels.unregister(&j.spec.id, &j.cancel);
            } else {
                members.push(j);
                taps.push(Instant::now());
            }
        }
    }
    shared.metrics.in_flight.fetch_add(members.len() as u64, Ordering::Relaxed);
    if members.len() == 1 {
        run_job(shared, pool, &members[0]);
    } else {
        for (j, tap) in members.iter().zip(&taps) {
            j.trace.record_at(SpanKind::FuseWait, *tap, tap.elapsed());
        }
        shared.metrics.add_batch(members.len() as u64, t0.elapsed().as_millis() as u64);
        run_batch(shared, &members, key.choice);
    }
    shared.metrics.in_flight.fetch_sub(members.len() as u64, Ordering::Relaxed);
    for j in &members {
        shared.cancels.unregister(&j.spec.id, &j.cancel);
    }
    for c in owed {
        shared.queue.done(c);
    }
}

/// Dispatch a fused group through one [`BatchedSession`]: one
/// standardize pass and one sweep per lock step for the whole group,
/// per-member progress and cancel at step boundaries, per-member
/// terminal frames, cache fills and metrics — bitwise the results each
/// member would have produced alone (`tests/batch_agreement.rs` pins
/// the session, the serve integration suite pins this path end to end).
fn run_batch(shared: &Shared, members: &[Job], choice: EngineChoice) {
    let t0 = Instant::now();
    let (workers, strategy) = incremental_params(choice).expect("fusable engine choice");
    let panels: Vec<Mat> = members
        .iter()
        .map(|j| match &j.spec.panel {
            PanelSource::Inline(m) => m.clone(),
            PanelSource::Csv(_) => unreachable!("fusion groups are inline-only"),
        })
        .collect();
    let mut session = match BatchedSession::with_strategy(&panels, workers, false, strategy) {
        Ok(s) => s,
        Err(e) => {
            // batch-level precondition failure: same-shape fusable groups
            // cannot actually hit this, but never panic a worker — fail
            // every member with the same error instead
            shared.metrics.jobs_failed.fetch_add(members.len() as u64, Ordering::Relaxed);
            let msg = e.to_string();
            for j in members {
                finish_trace(shared, j, "job_failed");
                (j.sink)(&protocol::frame_error(Some(j.spec.id.as_str()), &msg));
            }
            return;
        }
    };
    // one lock step is every live member's ordering step: book it into
    // the step histogram once and into each member's trace (a lane
    // dropped mid-batch keeps accruing — its wall clock really does run
    // until the batch finishes)
    let mut obs = BatchStepObserver { shared, members };
    let total = session.steps_total();
    while !session.finished() {
        let _ = session.step_live_observed(&mut obs);
        let step = session.steps_done();
        for (p, j) in members.iter().enumerate() {
            if !session.live(p) {
                continue;
            }
            if j.cancel.load(Ordering::Relaxed) {
                let reason = Error::Canceled(format!("fit canceled at step {step}/{total}"));
                session.drop_lane(p, reason);
            } else {
                let f0 = Instant::now();
                (j.sink)(&protocol::frame_progress(&j.spec.id, "ordering", step, total));
                j.trace.record_at(SpanKind::FrameFlush, f0, f0.elapsed());
            }
        }
    }
    let spec = choice.spec();
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    for (j, out) in members.iter().zip(session.into_fits(&panels, PruneMethod::default())) {
        // book the sweep work before the terminal frame, failed and
        // canceled lanes included (the solo path books the same way)
        shared.metrics.add_sweep(&out.counters);
        match out.result {
            Ok(fit) => {
                let payload = Arc::new(protocol::fit_data(
                    &spec,
                    &fit.order,
                    &fit.adjacency,
                    &out.counters,
                ));
                if let PanelSource::Inline(panel) = &j.spec.panel {
                    shared.cache.put(cache_key(panel, choice, &JobKind::Fit), payload.clone());
                }
                shared.metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
                shared.metrics.busy_ms_total.fetch_add(ms.round() as u64, Ordering::Relaxed);
                let rec = finish_trace(shared, j, "job_completed");
                (j.sink)(&protocol::frame_result_traced(
                    Some(j.spec.id.as_str()),
                    false,
                    ms,
                    &payload,
                    Some(&rec.timing_json()),
                ));
            }
            Err(Error::Canceled(_)) => {
                shared.metrics.jobs_canceled.fetch_add(1, Ordering::Relaxed);
                finish_trace(shared, j, "job_canceled");
                (j.sink)(&protocol::frame_canceled(&j.spec.id));
            }
            Err(e) => {
                shared.metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                finish_trace(shared, j, "job_failed");
                (j.sink)(&protocol::frame_error(Some(j.spec.id.as_str()), &e.to_string()));
            }
        }
    }
}

/// Observer installed on the batched lock-step loop: one histogram
/// observation per lock step, one `order_step` span increment per
/// member (see [`run_batch`]).
struct BatchStepObserver<'a> {
    shared: &'a Shared,
    members: &'a [Job],
}

impl StepObserver for BatchStepObserver<'_> {
    fn step_done(&mut self, _step: usize, _total: usize, elapsed: Duration) -> Result<()> {
        self.shared.metrics.hist_step.record(elapsed);
        for j in self.members {
            j.trace.record(SpanKind::OrderStep, elapsed);
        }
        Ok(())
    }
}

/// Execute one job end to end, translating the outcome into exactly one
/// terminal frame (`result`, `canceled` or `error`).
fn run_job(shared: &Shared, pool: &mut SessionPool, job: &Job) {
    let id = &job.spec.id;
    let t0 = Instant::now();
    if job.cancel.load(Ordering::Relaxed) {
        shared.metrics.jobs_canceled.fetch_add(1, Ordering::Relaxed);
        finish_trace(shared, job, "job_canceled");
        (job.sink)(&protocol::frame_canceled(id));
        return;
    }
    if matches!(job.spec.kind, JobKind::Watch { .. }) {
        // long-lived stream: its own driver loop, outside the
        // execute/cache path (streams are never cached)
        run_watch(shared, job);
        return;
    }
    match execute(shared, pool, job) {
        Ok((payload, cached)) => {
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            shared.metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
            shared.metrics.busy_ms_total.fetch_add(ms.round() as u64, Ordering::Relaxed);
            let rec = finish_trace(shared, job, "job_completed");
            (job.sink)(&protocol::frame_result_traced(
                Some(id.as_str()),
                cached,
                ms,
                &payload,
                Some(&rec.timing_json()),
            ));
        }
        Err(Error::Canceled(_)) => {
            shared.metrics.jobs_canceled.fetch_add(1, Ordering::Relaxed);
            finish_trace(shared, job, "job_canceled");
            (job.sink)(&protocol::frame_canceled(id));
        }
        Err(e) => {
            shared.metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
            finish_trace(shared, job, "job_failed");
            (job.sink)(&protocol::frame_error(Some(id.as_str()), &e.to_string()));
        }
    }
}

fn execute(shared: &Shared, pool: &mut SessionPool, job: &Job) -> Result<(Arc<String>, bool)> {
    let choice = EngineChoice::parse(&job.spec.engine)?.resolve_workers(shared.worker_count);
    let loaded;
    let panel: &Mat = match &job.spec.panel {
        PanelSource::Inline(m) => m,
        PanelSource::Csv(path) => {
            let (_header, m) = crate::data::read_csv(std::path::Path::new(path))?;
            loaded = m;
            &loaded
        }
    };
    // the reader already short-circuits inline panels, but the key is
    // re-checked here so CSV panels (hashable only after loading) and
    // identical inline jobs that were queued concurrently still hit
    let probe = Instant::now();
    let key = cache_key(panel, choice, &job.spec.kind);
    let hit = shared.cache.get(key);
    job.trace.record_at(SpanKind::CacheProbe, probe, probe.elapsed());
    if let Some(hit) = hit {
        return Ok((hit, true));
    }
    let payload = match &job.spec.kind {
        // partition is an ordering plan, not a session engine: dispatch
        // it before the session-backed paths (`run_fit`'s non-pooled arm
        // falls through to XLA, and `build_engine` rejects partition)
        JobKind::Fit => match choice {
            EngineChoice::Partition { blocks } => {
                run_partition_fit(shared, job, panel, choice, blocks)?
            }
            _ => run_fit(shared, pool, job, panel, choice)?,
        },
        JobKind::Bootstrap { resamples, seed, threshold, workers } => {
            let opts = BootstrapOpts {
                resamples: *resamples,
                workers: (*workers).max(1),
                seed: *seed,
                ..Default::default()
            };
            match choice {
                EngineChoice::Partition { blocks } => {
                    run_partition_bootstrap(shared, job, panel, blocks, &opts, *threshold)?
                }
                _ => run_bootstrap(shared, job, panel, choice, &opts, *threshold)?,
            }
        }
        JobKind::Var { lags } => run_var(shared, job, panel, choice, *lags)?,
        // run_job dispatches watch jobs to `run_watch` before this path
        // and the fusion window only admits fits, so a watch kind here
        // is a routing bug — fail it cleanly rather than panic a worker
        JobKind::Watch { .. } => {
            return Err(Error::InvalidArgument(
                "watch streams run outside the execute/cache path".into(),
            ))
        }
    };
    let payload = Arc::new(payload);
    shared.cache.put(key, payload.clone());
    Ok((payload, false))
}

/// Content hash of a request's full semantic identity: job kind +
/// options, canonical engine spec, panel dims and sample bit patterns.
/// Byte-identical requests — and only they — collide, so a cache hit is
/// a replay of the exact same computation.
pub(super) fn cache_key(panel: &Mat, choice: EngineChoice, kind: &JobKind) -> u128 {
    let mut h = Fnv128::new();
    match kind {
        JobKind::Fit => h.write_str("fit"),
        JobKind::Bootstrap { resamples, seed, threshold, workers: _ } => {
            // `workers` changes scheduling, never the estimate, so it is
            // deliberately outside the key
            h.write_str("bootstrap");
            h.write_u64(*resamples as u64);
            h.write_u64(*seed);
            h.write_f64_bits(*threshold);
        }
        JobKind::Var { lags } => {
            h.write_str("varlingam");
            h.write_u64(*lags as u64);
        }
        // watch streams are live and never cached; the arm keeps the
        // hash total over the job kinds
        JobKind::Watch { dim, window, lags, resync_every, drift_tol, threshold } => {
            h.write_str("watch");
            h.write_u64(*dim as u64);
            h.write_u64(*window as u64);
            h.write_u64(*lags as u64);
            h.write_u64(*resync_every as u64);
            h.write_f64_bits(*drift_tol);
            h.write_f64_bits(*threshold);
        }
    }
    h.write_str(&choice.spec());
    h.write_u64(panel.rows() as u64);
    h.write_u64(panel.cols() as u64);
    for &v in panel.as_slice() {
        h.write_f64_bits(v);
    }
    h.finish()
}

/// `(workers, strategy)` for choices whose session is the owned
/// [`IncrementalSession`] workspace (poolable across jobs); `None` for
/// engines whose sessions borrow the engine.
fn incremental_params(choice: EngineChoice) -> Option<(usize, SweepStrategy)> {
    match choice {
        EngineChoice::Vectorized => Some((1, SweepStrategy::Exact)),
        EngineChoice::Parallel { workers } => Some((workers.max(1), SweepStrategy::Exact)),
        EngineChoice::Pruned { workers } => Some((workers.max(1), SweepStrategy::Pruned)),
        EngineChoice::Sequential | EngineChoice::Partition { .. } | EngineChoice::Xla => None,
    }
}

fn run_fit(
    shared: &Shared,
    pool: &mut SessionPool,
    job: &Job,
    panel: &Mat,
    choice: EngineChoice,
) -> Result<String> {
    validate_panel(panel)?;
    let spec = choice.spec();
    let (outcome, counters) = match incremental_params(choice) {
        Some((workers, strategy)) => {
            let key = PoolKey {
                n: panel.rows(),
                d: panel.cols(),
                workers,
                pruned: strategy == SweepStrategy::Pruned,
            };
            let acquire = Instant::now();
            let mut session = match pool.remove(&key) {
                Some(mut parked) => {
                    parked.reset(panel)?;
                    parked
                }
                None => IncrementalSession::with_strategy(panel, workers, false, strategy)?,
            };
            job.trace.record_at(SpanKind::SessionAcquire, acquire, acquire.elapsed());
            let outcome = drive_fit(shared, &mut session, panel, job);
            let counters = session.sweep_counters();
            if pool.len() >= MAX_PARKED_SESSIONS {
                if let Some(evict) = pool.keys().next().copied() {
                    pool.remove(&evict);
                }
            }
            pool.insert(key, session);
            (outcome, counters)
        }
        None => {
            let seq_engine;
            let xla_engine;
            let acquire = Instant::now();
            let mut session: Box<dyn OrderingSession + '_> = match choice {
                EngineChoice::Sequential => {
                    seq_engine = SequentialEngine;
                    seq_engine.session(panel)?
                }
                _ => {
                    xla_engine = shared.xla_engine()?;
                    xla_engine.session(panel)?
                }
            };
            job.trace.record_at(SpanKind::SessionAcquire, acquire, acquire.elapsed());
            let outcome = drive_fit(shared, session.as_mut(), panel, job);
            let counters = session.sweep_counters();
            (outcome, counters)
        }
    };
    // book the sweep work before bailing, so even a canceled or failed
    // fit's visited pairs show up in the server metrics
    shared.metrics.add_sweep(&counters);
    let fit = outcome?;
    // the regression is timed inside the fit's stage profile; lift it
    // into the trace post hoc (it runs after the last observed step)
    let reg = fit.profile.secs("regression");
    if reg > 0.0 {
        job.trace.record(SpanKind::Regression, Duration::from_secs_f64(reg));
    }
    Ok(protocol::fit_data(&spec, &fit.order, &fit.adjacency, &counters))
}

/// Observer installed on the solo fit loop: per-step latency into the
/// step histogram and the job's `order_step` span, progress frames
/// (timed as `frame_flush`), and a raised cancel flag turned into
/// [`Error::Canceled`] at the step boundary.
struct ServeStepObserver<'a> {
    shared: &'a Shared,
    job: &'a Job,
}

impl StepObserver for ServeStepObserver<'_> {
    fn step_done(&mut self, step: usize, total: usize, elapsed: Duration) -> Result<()> {
        self.shared.metrics.hist_step.record(elapsed);
        self.job.trace.record(SpanKind::OrderStep, elapsed);
        if self.job.cancel.load(Ordering::Relaxed) {
            return Err(Error::Canceled(format!("fit canceled at step {step}/{total}")));
        }
        let f0 = Instant::now();
        (self.job.sink)(&protocol::frame_progress(&self.job.spec.id, "ordering", step, total));
        self.job.trace.record_at(SpanKind::FrameFlush, f0, f0.elapsed());
        Ok(())
    }
}

/// The serve fit driver: `DirectLingam::fit_session_stepped` — the one
/// shared d−1-step loop — with [`ServeStepObserver`] installed.
fn drive_fit(
    shared: &Shared,
    session: &mut dyn OrderingSession,
    panel: &Mat,
    job: &Job,
) -> Result<LingamFit> {
    let mut obs = ServeStepObserver { shared, job };
    DirectLingam::new().fit_session_stepped(panel, session, &mut obs)
}

/// Partitioned fit: route through [`DirectLingam::fit_plan`] with a
/// [`PartitionedPlan`] (exact merge — the serve path never trades
/// accuracy silently). The plan owns its block decomposition and merge,
/// so the parked workspace pool does not apply; the plan runs
/// monolithically, so progress is coarse (one `ordering` stage frame on
/// each side) and cancellation is checked up front only.
fn run_partition_fit(
    shared: &Shared,
    job: &Job,
    panel: &Mat,
    choice: EngineChoice,
    blocks: usize,
) -> Result<String> {
    if job.cancel.load(Ordering::Relaxed) {
        return Err(Error::Canceled("partition fit canceled before start".into()));
    }
    (job.sink)(&protocol::frame_progress(&job.spec.id, "ordering", 0, 1));
    let plan =
        PartitionedPlan::with_blocks(blocks, EngineChoice::per_job_workers(shared.worker_count));
    let pf = DirectLingam::new().fit_plan(panel, &plan)?;
    (job.sink)(&protocol::frame_progress(&job.spec.id, "ordering", 1, 1));
    shared.metrics.add_sweep(&pf.counters);
    shared.metrics.add_partition(pf.blocks_formed, pf.boundary_pairs);
    Ok(protocol::fit_data(&choice.spec(), &pf.fit.order, &pf.fit.adjacency, &pf.counters))
}

/// Partitioned bootstrap: same resample/pool/aggregate loop as
/// [`run_bootstrap`], but the pooled workspaces are
/// [`PartitionWorkspace`](crate::lingam::PartitionWorkspace)s
/// (`build_engine` rejects partition, so the engine-backed path cannot
/// serve it).
fn run_partition_bootstrap(
    shared: &Shared,
    job: &Job,
    panel: &Mat,
    blocks: usize,
    opts: &BootstrapOpts,
    threshold: f64,
) -> Result<String> {
    let spec = PartitionSpec {
        max_blocks: blocks,
        workers: EngineChoice::per_job_workers(shared.worker_count),
        ..PartitionSpec::default()
    };
    let (id, sink) = (&job.spec.id, &job.sink);
    let result = bootstrap_partition_observed(
        panel,
        &spec,
        opts,
        Some(&*job.cancel),
        |done, total| sink(&protocol::frame_progress(id, "bootstrap", done, total)),
    )?;
    Ok(protocol::bootstrap_data(&EngineChoice::Partition { blocks }.spec(), &result, threshold))
}

fn run_bootstrap(
    shared: &Shared,
    job: &Job,
    panel: &Mat,
    choice: EngineChoice,
    opts: &BootstrapOpts,
    threshold: f64,
) -> Result<String> {
    let engine = shared.build_engine(choice)?;
    let (id, sink) = (&job.spec.id, &job.sink);
    let result = bootstrap_direct_observed(
        panel,
        engine.as_ordering(),
        opts,
        Some(&*job.cancel),
        |done, total| sink(&protocol::frame_progress(id, "bootstrap", done, total)),
    )?;
    Ok(protocol::bootstrap_data(&choice.spec(), &result, threshold))
}

fn run_var(
    shared: &Shared,
    job: &Job,
    panel: &Mat,
    choice: EngineChoice,
    lags: usize,
) -> Result<String> {
    if job.cancel.load(Ordering::Relaxed) {
        return Err(Error::Canceled("varlingam canceled before start".into()));
    }
    // VarLiNGAM's inner fit is monolithic: coarse stage progress only
    (job.sink)(&protocol::frame_progress(&job.spec.id, "varlingam", 0, 1));
    let engine = shared.build_engine(choice)?;
    let fit = VarLingam::new().with_lags(lags).fit(panel, engine.as_ordering())?;
    (job.sink)(&protocol::frame_progress(&job.spec.id, "varlingam", 1, 1));
    Ok(protocol::var_data(&choice.spec(), &fit))
}

/// How often a parked watch stream re-checks its cancel flag and the
/// queue's open state while waiting for samples.
const WATCH_POLL_MS: u64 = 50;

/// The sliding-window driver behind one watch stream: `lags == 0` is
/// plain DirectLiNGAM over the window, otherwise the lag-k VAR variant.
enum WatchDriver {
    Plain(StreamingLingam),
    Var(StreamingVarLingam),
}

/// One emitted adjacency frame, driver-agnostic: the booking fields
/// plus the already-rendered `watch` data payload.
struct WatchFrame {
    refit: RefitKind,
    resynced: bool,
    drift_bound: f64,
    counters: SweepCounters,
    data: String,
}

impl WatchDriver {
    fn new(
        dim: usize,
        window: usize,
        lags: usize,
        cfg: StreamingConfig,
        workers: usize,
        strategy: SweepStrategy,
        threshold: f64,
    ) -> Result<WatchDriver> {
        Ok(if lags == 0 {
            WatchDriver::Plain(StreamingLingam::with_options(
                dim, window, cfg, workers, strategy, threshold,
            )?)
        } else {
            WatchDriver::Var(StreamingVarLingam::with_options(
                dim, lags, window, cfg, workers, strategy, threshold,
            )?)
        })
    }

    fn warm(&mut self, row: &[f64]) -> Result<()> {
        match self {
            WatchDriver::Plain(s) => s.warm(row),
            WatchDriver::Var(s) => s.warm(row),
        }
    }

    /// Ingest one sample, turning a raised cancel flag into
    /// [`Error::Canceled`] at full-refit step boundaries (incremental
    /// frames are too short to need interior cancel points).
    fn ingest(&mut self, row: &[f64], cancel: &AtomicBool) -> Result<Option<WatchFrame>> {
        let mut observer = |step: usize, total: usize| {
            if cancel.load(Ordering::Relaxed) {
                return Err(Error::Canceled(format!(
                    "watch canceled at refit step {step}/{total}"
                )));
            }
            Ok(())
        };
        match self {
            WatchDriver::Plain(s) => Ok(s.ingest_observed(row, &mut observer)?.map(|o| {
                WatchFrame {
                    refit: o.refit,
                    resynced: o.resynced,
                    drift_bound: o.drift_bound,
                    counters: o.counters,
                    data: protocol::watch_update_data(&o.order, &o.b0, &[]),
                }
            })),
            WatchDriver::Var(s) => Ok(s.ingest_observed(row, &mut observer)?.map(|o| {
                WatchFrame {
                    refit: o.refit,
                    resynced: o.resynced,
                    drift_bound: o.drift_bound,
                    // incremental VAR frames run no sweep; full refits
                    // book through the plain driver inside the fit
                    counters: SweepCounters::default(),
                    data: protocol::watch_update_data(&o.order, &o.b0, &o.b_tau),
                }
            })),
        }
    }

    fn refits_incremental(&self) -> u64 {
        match self {
            WatchDriver::Plain(s) => s.refits_incremental(),
            WatchDriver::Var(s) => s.refits_incremental(),
        }
    }

    fn refits_full(&self) -> u64 {
        match self {
            WatchDriver::Plain(s) => s.refits_full(),
            WatchDriver::Var(s) => s.refits_full(),
        }
    }

    fn resyncs(&self) -> u64 {
        match self {
            WatchDriver::Plain(s) => s.window().resyncs(),
            WatchDriver::Var(s) => s.window().resyncs(),
        }
    }
}

/// Terminal disposition of a watch stream's sample loop.
enum WatchEnd {
    /// The client sent `end`: summary `result` frame.
    Ended,
    /// Server shutdown closed the queue: drained with the same summary
    /// `result` frame (the stream completed, just on the server's clock).
    Drained,
    /// Cancel flag raised (a `cancel` frame or client detach).
    Canceled,
    /// The sample channel dropped without `end` — the connection
    /// vanished, so the terminal frame has no reader anyway.
    Disconnected,
    /// A sample failed to ingest (wrong arity, non-finite values).
    Failed(Error),
}

/// Drive one watch stream to completion: pull samples off the job's
/// channel, feed the sliding-window driver, emit one `adjacency` frame
/// per full-window sample and exactly one terminal frame. Holds this
/// worker (and the client's queue lane) for the stream's lifetime —
/// documented, deliberate: a stream is a standing computation, not a
/// queued unit.
fn run_watch(shared: &Shared, job: &Job) {
    let id = &job.spec.id;
    let JobKind::Watch { dim, window, lags, resync_every, drift_tol, threshold } = job.spec.kind
    else {
        unreachable!("run_watch routed a non-watch job");
    };
    let fail = |msg: &str| {
        shared.metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
        finish_trace(shared, job, "job_failed");
        (job.sink)(&protocol::frame_error(Some(id.as_str()), msg));
    };
    let Some(rx) = job.watch_rx.as_ref() else {
        fail("watch job carries no sample channel (relay tiers do not host streams)");
        return;
    };
    let choice = match EngineChoice::parse(&job.spec.engine) {
        Ok(c) => c.resolve_workers(shared.worker_count),
        Err(e) => {
            fail(&e.to_string());
            return;
        }
    };
    // streams re-seed a session per full refit from the maintained
    // moments, so only engines with an incremental workspace apply
    let Some((workers, strategy)) = incremental_params(choice) else {
        fail(&format!(
            "engine `{}` has no incremental workspace; watch streams need \
             vectorized, parallel or pruned",
            choice.spec()
        ));
        return;
    };
    let cfg = StreamingConfig { resync_every, drift_tol };
    let mut driver = match WatchDriver::new(dim, window, lags, cfg, workers, strategy, threshold) {
        Ok(d) => d,
        Err(e) => {
            fail(&e.to_string());
            return;
        }
    };
    // an inline seed panel pre-fills the window without emitting frames
    match &job.spec.panel {
        PanelSource::Inline(panel) => {
            for r in 0..panel.rows() {
                if let Err(e) = driver.warm(panel.row(r)) {
                    fail(&e.to_string());
                    return;
                }
            }
        }
        PanelSource::Csv(_) => {
            fail("watch seed panels must be inline");
            return;
        }
    }
    shared.metrics.watch_streams.fetch_add(1, Ordering::Relaxed);
    let t0 = Instant::now();
    let mut ingested: u64 = 0;
    let mut busy_ms = 0.0f64;
    let end = loop {
        if job.cancel.load(Ordering::Relaxed) {
            break WatchEnd::Canceled;
        }
        if !shared.queue.is_open() {
            break WatchEnd::Drained;
        }
        match rx.recv_timeout(Duration::from_millis(WATCH_POLL_MS)) {
            Ok(WatchInput::Row(row)) => {
                let f0 = Instant::now();
                ingested += 1;
                shared.metrics.frames_ingested.fetch_add(1, Ordering::Relaxed);
                let fit = driver.ingest(&row, &job.cancel);
                let dt = f0.elapsed();
                job.trace.record_at(SpanKind::Stream, f0, dt);
                shared.metrics.hist_watch_frame.record(dt);
                let ms = dt.as_secs_f64() * 1e3;
                busy_ms += ms;
                match fit {
                    // window still warming: no frame to emit yet
                    Ok(None) => {}
                    Ok(Some(frame)) => {
                        shared.metrics.add_sweep(&frame.counters);
                        match frame.refit {
                            RefitKind::Incremental => {
                                shared.metrics.refits_incremental.fetch_add(1, Ordering::Relaxed)
                            }
                            RefitKind::Full => {
                                shared.metrics.refits_full.fetch_add(1, Ordering::Relaxed)
                            }
                        };
                        if frame.resynced {
                            shared.metrics.resyncs.fetch_add(1, Ordering::Relaxed);
                        }
                        let w0 = Instant::now();
                        (job.sink)(&protocol::frame_adjacency(
                            id,
                            ingested,
                            frame.refit.as_str(),
                            frame.resynced,
                            frame.drift_bound,
                            ms,
                            &frame.data,
                        ));
                        job.trace.record_at(SpanKind::FrameFlush, w0, w0.elapsed());
                    }
                    Err(Error::Canceled(_)) => break WatchEnd::Canceled,
                    Err(e) => break WatchEnd::Failed(e),
                }
            }
            Ok(WatchInput::End) => break WatchEnd::Ended,
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break WatchEnd::Disconnected,
        }
    };
    shared.metrics.watch_streams.fetch_sub(1, Ordering::Relaxed);
    match end {
        WatchEnd::Ended | WatchEnd::Drained => {
            shared.metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
            shared.metrics.busy_ms_total.fetch_add(busy_ms.round() as u64, Ordering::Relaxed);
            let data = protocol::watch_summary_data(
                &choice.spec(),
                ingested,
                driver.refits_incremental(),
                driver.refits_full(),
                driver.resyncs(),
            );
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            let rec = finish_trace(shared, job, "job_completed");
            (job.sink)(&protocol::frame_result_traced(
                Some(id.as_str()),
                false,
                ms,
                &data,
                Some(&rec.timing_json()),
            ));
        }
        WatchEnd::Canceled | WatchEnd::Disconnected => {
            shared.metrics.jobs_canceled.fetch_add(1, Ordering::Relaxed);
            finish_trace(shared, job, "job_canceled");
            (job.sink)(&protocol::frame_canceled(id));
        }
        WatchEnd::Failed(e) => {
            shared.metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
            finish_trace(shared, job, "job_failed");
            (job.sink)(&protocol::frame_error(Some(id.as_str()), &e.to_string()));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn panel() -> Mat {
        Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, -6.0]])
    }

    #[test]
    fn cache_key_is_deterministic_and_content_sensitive() {
        let p = panel();
        let base = cache_key(&p, EngineChoice::Vectorized, &JobKind::Fit);
        assert_eq!(base, cache_key(&p, EngineChoice::Vectorized, &JobKind::Fit));
        // engine, kind, options and panel bits all separate keys
        assert_ne!(base, cache_key(&p, EngineChoice::Sequential, &JobKind::Fit));
        assert_ne!(base, cache_key(&p, EngineChoice::Vectorized, &JobKind::Var { lags: 1 }));
        let boot = JobKind::Bootstrap { resamples: 10, seed: 0, threshold: 0.5, workers: 1 };
        let boot2 = JobKind::Bootstrap { resamples: 11, seed: 0, threshold: 0.5, workers: 1 };
        assert_ne!(
            cache_key(&p, EngineChoice::Vectorized, &boot),
            cache_key(&p, EngineChoice::Vectorized, &boot2)
        );
        let mut p2 = panel();
        p2[(0, 0)] = 1.0000000001;
        assert_ne!(base, cache_key(&p2, EngineChoice::Vectorized, &JobKind::Fit));
    }

    #[test]
    fn bootstrap_worker_count_is_not_part_of_the_key() {
        let p = panel();
        let a = JobKind::Bootstrap { resamples: 10, seed: 1, threshold: 0.5, workers: 1 };
        let b = JobKind::Bootstrap { resamples: 10, seed: 1, threshold: 0.5, workers: 4 };
        assert_eq!(
            cache_key(&p, EngineChoice::Vectorized, &a),
            cache_key(&p, EngineChoice::Vectorized, &b)
        );
    }

    #[test]
    fn incremental_params_route_engines_correctly() {
        assert_eq!(
            incremental_params(EngineChoice::Vectorized),
            Some((1, SweepStrategy::Exact))
        );
        assert_eq!(
            incremental_params(EngineChoice::Parallel { workers: 3 }),
            Some((3, SweepStrategy::Exact))
        );
        assert_eq!(
            incremental_params(EngineChoice::Pruned { workers: 2 }),
            Some((2, SweepStrategy::Pruned))
        );
        assert_eq!(incremental_params(EngineChoice::Sequential), None);
        // partition is dispatched to the plan layer before run_fit ever
        // sees it; routing it to a pooled session here would be a bug
        assert_eq!(incremental_params(EngineChoice::Partition { blocks: 0 }), None);
        assert_eq!(incremental_params(EngineChoice::Partition { blocks: 4 }), None);
        assert_eq!(incremental_params(EngineChoice::Xla), None);
    }

    fn job(engine: &str, panel: PanelSource, kind: JobKind) -> Job {
        Job {
            spec: JobSpec { id: "j".into(), panel, engine: engine.into(), kind, trace: 0 },
            cancel: Arc::new(AtomicBool::new(false)),
            sink: Arc::new(|_| {}),
            watch_rx: None,
            trace: Arc::new(TraceBuilder::mint("j")),
        }
    }

    fn watch_kind(window: usize) -> JobKind {
        JobKind::Watch {
            dim: 2,
            window,
            lags: 0,
            resync_every: 64,
            drift_tol: 1e-8,
            threshold: 0.05,
        }
    }

    #[test]
    fn watch_jobs_are_structurally_excluded_from_fusion() {
        // the fusion window only admits fits: a watch job can never fuse,
        // whatever its engine, so long-lived streams cannot capture the
        // batched lock-step path
        let inline = || PanelSource::Inline(panel());
        assert_eq!(fuse_key(4, &job("vectorized", inline(), watch_kind(64))), None);
        assert_eq!(fuse_key(4, &job("parallel", inline(), watch_kind(64))), None);
    }

    #[test]
    fn watch_cache_keys_are_distinct_per_configuration() {
        let p = panel();
        let base = cache_key(&p, EngineChoice::Vectorized, &watch_kind(64));
        assert_ne!(base, cache_key(&p, EngineChoice::Vectorized, &JobKind::Fit));
        assert_ne!(base, cache_key(&p, EngineChoice::Vectorized, &watch_kind(128)));
        let var_watch = JobKind::Watch {
            dim: 2,
            window: 64,
            lags: 2,
            resync_every: 64,
            drift_tol: 1e-8,
            threshold: 0.05,
        };
        assert_ne!(base, cache_key(&p, EngineChoice::Vectorized, &var_watch));
    }

    #[test]
    fn fuse_key_routes_only_inline_incremental_fits() {
        let inline = || PanelSource::Inline(panel());
        // incremental engines on inline fits are fusable, keyed by shape
        // and the *resolved* engine configuration
        let key = fuse_key(4, &job("vectorized", inline(), JobKind::Fit)).expect("fusable");
        assert_eq!(key, FuseKey { n: 3, d: 2, choice: EngineChoice::Vectorized });
        assert_eq!(
            fuse_key(4, &job("pruned:2", inline(), JobKind::Fit)).map(|k| k.choice),
            Some(EngineChoice::Pruned { workers: 2 })
        );
        // auto worker counts resolve before keying, so an auto spec and
        // its resolved form land in the same fusion group
        let auto = fuse_key(4, &job("parallel", inline(), JobKind::Fit)).expect("fusable");
        assert!(!matches!(auto.choice, EngineChoice::Parallel { workers: 0 }));
        let pinned = format!("parallel:{}", EngineChoice::per_job_workers(4));
        assert_eq!(Some(auto), fuse_key(4, &job(&pinned, inline(), JobKind::Fit)));
        // everything else runs the per-job path
        assert_eq!(fuse_key(4, &job("sequential", inline(), JobKind::Fit)), None);
        assert_eq!(fuse_key(4, &job("partition", inline(), JobKind::Fit)), None);
        assert_eq!(fuse_key(4, &job("xla", inline(), JobKind::Fit)), None);
        assert_eq!(fuse_key(4, &job("no-such-engine", inline(), JobKind::Fit)), None);
        let csv = PanelSource::Csv("/tmp/panel.csv".into());
        assert_eq!(fuse_key(4, &job("vectorized", csv, JobKind::Fit)), None);
        let boot = JobKind::Bootstrap { resamples: 4, seed: 0, threshold: 0.5, workers: 1 };
        assert_eq!(fuse_key(4, &job("vectorized", inline(), boot)), None);
        assert_eq!(fuse_key(4, &job("vectorized", inline(), JobKind::Var { lags: 1 })), None);
    }
}
