//! `http` — the std-only HTTP/1.1 + SSE front on the serve core.
//!
//! One listener (enabled with `--http-addr`) maps a small fixed route
//! table onto the exact machinery behind the TCP front:
//!
//! | route             | behavior                                        |
//! |-------------------|-------------------------------------------------|
//! | `POST /fit`       | submit a fit job; stream frames as SSE          |
//! | `POST /bootstrap` | submit a bootstrap job; stream frames as SSE    |
//! | `POST /varlingam` | submit a VAR-LiNGAM job (alias `POST /var`)     |
//! | `POST /watch`     | replay a `"frames"` array through a watch       |
//! |                   | stream; one SSE `adjacency` event per frame     |
//! | `GET  /status`    | one `status` frame as `application/json`        |
//! | `GET  /metrics`   | one `metrics` frame as `application/json`; with |
//! |                   | `?format=prometheus`, the text exposition       |
//! | `GET  /trace/<t>` | replay a completed job's recorded trace (by     |
//! |                   | trace id or job id); 404 when none matches      |
//! | `GET  /healthz`   | liveness: `{"ok":true}` without touching the    |
//! |                   | backend (safe for load-balancer probes)         |
//! | `POST /cancel`    | flip cancel flags; ack as `application/json`    |
//! | `POST /shutdown`  | request shutdown; ack as `application/json`     |
//!
//! HTTP is request/response, so the interactive half of the watch
//! protocol (trickling `frame` lines onto an open connection) belongs
//! to the TCP front; `POST /watch` is the batch replay form — the body
//! carries the subscription options plus a `"frames"` array of rows,
//! the server feeds them through the stream in order and the SSE
//! response carries every per-frame `adjacency` event plus the terminal
//! summary `result`. Same sliding-window engine, same frames, one
//! round trip.
//!
//! The request body of a job `POST` is the TCP request frame minus its
//! `cmd` field (implied by the path); both fronts build requests through
//! [`protocol::request_from_parts`], so payloads are byte-identical —
//! see the equivalence section in the [`protocol`] docs. Job responses
//! stream as Server-Sent Events: each protocol frame (a single line of
//! JSON) becomes one `data: <frame>\n\n` event, flushed as it happens,
//! ending with the terminal `result`/`error`/`canceled` event, after
//! which the connection closes (`Connection: close`; one request per
//! connection keeps the parser trivial and is what SSE clients expect).
//!
//! # Parser bounds — never panic, never balloon
//!
//! The request parser is total and bounded: request/header lines are
//! capped at [`MAX_LINE_BYTES`] (431 past that), at most
//! [`MAX_HEADERS`] headers are read, bodies require `Content-Length`
//! (`Transfer-Encoding` is rejected with 501) and are capped at
//! [`MAX_BODY_BYTES`] (413 past that). `Expect: 100-continue` is
//! honored — the interim `100 Continue` goes out before the body read —
//! because `curl` sends it for bodies over 1 KiB and would otherwise
//! stall. Anything malformed gets a real HTTP error status with a
//! protocol `error` frame as the body; nothing in this module can panic
//! on wire input.

use super::protocol::{self, Json};
use super::{worker, Backend, WatchInput};
use crate::util::table::json_escape;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Longest accepted request or header line, bytes (431 past this).
pub const MAX_LINE_BYTES: usize = 8 * 1024;
/// Most headers read before the request is rejected with 431.
pub const MAX_HEADERS: usize = 64;
/// Largest accepted `Content-Length` (413 past this). Generous: inline
/// panels are the payload, and 64 MiB is ~8M f64 cells as JSON text.
pub const MAX_BODY_BYTES: usize = 64 * 1024 * 1024;

/// How long a job stream may run before the front gives up waiting for
/// its terminal frame and closes the connection (defense in depth — the
/// backend guarantees a terminal frame on every submit path).
const JOB_DEADLINE: Duration = Duration::from_secs(600);

/// A parsed request: method, path, raw query string (empty when the
/// target carried none), and the full body.
struct HttpRequest {
    method: String,
    path: String,
    query: String,
    body: String,
}

/// Why a request could not be served: a status to answer with, or a
/// connection that died mid-request (nothing to say to it).
enum Reject {
    Status(u16, &'static str, String),
    Gone,
}

fn reject(code: u16, reason: &'static str, msg: &str) -> Reject {
    Reject::Status(code, reason, protocol::frame_error(None, msg))
}

/// Serve exactly one HTTP request on `stream` against `backend`.
pub(crate) fn handle_http(stream: TcpStream, backend: Arc<dyn Backend>) {
    // bound the header/body read so a stalled client cannot pin this
    // thread, and writes so a non-reading client drops frames instead
    // of wedging the drain
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(30)));
    let mut reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => return,
    };
    let mut out = stream;
    let req = match read_request(&mut reader, &mut out) {
        Ok(req) => req,
        Err(Reject::Status(code, reason, body)) => {
            write_simple(&mut out, code, reason, "application/json", &(body + "\n"));
            return;
        }
        Err(Reject::Gone) => return,
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/status") => {
            let frame = backend.status_frame(None);
            write_simple(&mut out, 200, "OK", "application/json", &(frame + "\n"));
        }
        ("GET", "/metrics") => {
            if query_has(&req.query, "format", "prometheus") {
                let text = backend.prometheus_text();
                write_simple(
                    &mut out,
                    200,
                    "OK",
                    "text/plain; version=0.0.4; charset=utf-8",
                    &text,
                );
            } else {
                let frame = backend.metrics_frame(None);
                write_simple(&mut out, 200, "OK", "application/json", &(frame + "\n"));
            }
        }
        ("GET", p) if p.strip_prefix("/trace/").is_some_and(|t| !t.is_empty()) => {
            let target = p.strip_prefix("/trace/").unwrap_or("");
            match backend.trace_lookup(target) {
                Some(body) => {
                    let payload = format!("{{\"event\":\"trace\",\"found\":true,{body}}}\n");
                    write_simple(&mut out, 200, "OK", "application/json", &payload);
                }
                None => {
                    let payload = format!(
                        "{{\"event\":\"trace\",\"found\":false,\"target\":\"{}\"}}\n",
                        json_escape(target)
                    );
                    write_simple(&mut out, 404, "Not Found", "application/json", &payload);
                }
            }
        }
        // liveness, not readiness: answered from this front thread alone
        // so a wedged backend (or a fleet mid-restart) never turns probe
        // traffic into queued work or a hung health check
        ("GET", "/healthz") => {
            write_simple(&mut out, 200, "OK", "application/json", "{\"ok\":true}\n");
        }
        ("POST", "/fit") => run_job(out, &backend, "fit", &req.body),
        ("POST", "/bootstrap") => run_job(out, &backend, "bootstrap", &req.body),
        ("POST", "/varlingam") | ("POST", "/var") => run_job(out, &backend, "varlingam", &req.body),
        ("POST", "/watch") => run_watch_replay(out, &backend, &req.body),
        ("POST", "/cancel") => run_control(&mut out, &backend, "cancel", &req.body),
        ("POST", "/shutdown") => run_control(&mut out, &backend, "shutdown", &req.body),
        (
            _,
            "/status" | "/metrics" | "/healthz" | "/fit" | "/bootstrap" | "/varlingam" | "/var"
            | "/watch" | "/cancel" | "/shutdown",
        ) => {
            let body = protocol::frame_error(None, &format!("method not allowed on {}", req.path));
            write_simple(&mut out, 405, "Method Not Allowed", "application/json", &(body + "\n"));
        }
        _ => {
            let body = protocol::frame_error(None, &format!("no such route: {}", req.path));
            write_simple(&mut out, 404, "Not Found", "application/json", &(body + "\n"));
        }
    }
}

/// Read one bounded CRLF/LF-terminated line. `Ok(None)` means the line
/// exceeded [`MAX_LINE_BYTES`]; `Err` wraps io failure or clean EOF.
fn read_line(reader: &mut BufReader<TcpStream>) -> std::result::Result<Option<String>, Reject> {
    let mut buf = Vec::new();
    // +1 so a line of exactly MAX_LINE_BYTES (newline included) passes
    // and the overflow case is detectable as "limit hit, no newline"
    let got = (&mut *reader)
        .take(MAX_LINE_BYTES as u64 + 1)
        .read_until(b'\n', &mut buf)
        .map_err(|_| Reject::Gone)?;
    if got == 0 {
        return Err(Reject::Gone);
    }
    if buf.last() != Some(&b'\n') {
        return Ok(None);
    }
    while matches!(buf.last(), Some(b'\n' | b'\r')) {
        buf.pop();
    }
    match String::from_utf8(buf) {
        Ok(s) => Ok(Some(s)),
        Err(_) => Err(reject(400, "Bad Request", "request line is not UTF-8")),
    }
}

/// Parse the request line, headers and body. Writes the interim
/// `100 Continue` to `out` when the client asked for it.
fn read_request(
    reader: &mut BufReader<TcpStream>,
    out: &mut TcpStream,
) -> std::result::Result<HttpRequest, Reject> {
    let line = read_line(reader)?
        .ok_or_else(|| reject(431, "Request Header Fields Too Large", "request line too long"))?;
    let mut parts = line.split_whitespace();
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(t), Some(v), None) => (m.to_string(), t.to_string(), v),
        _ => return Err(reject(400, "Bad Request", "malformed request line")),
    };
    if !version.starts_with("HTTP/1.") {
        return Err(reject(505, "HTTP Version Not Supported", "only HTTP/1.x is served"));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target, String::new()),
    };
    let mut content_length: usize = 0;
    let mut expect_continue = false;
    let mut count = 0usize;
    loop {
        let line = read_line(reader)?
            .ok_or_else(|| reject(431, "Request Header Fields Too Large", "header line too long"))?;
        if line.is_empty() {
            break;
        }
        count += 1;
        if count > MAX_HEADERS {
            return Err(reject(431, "Request Header Fields Too Large", "too many headers"));
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(reject(400, "Bad Request", "malformed header line"));
        };
        let name = name.trim().to_ascii_lowercase();
        let value = value.trim();
        match name.as_str() {
            "content-length" => {
                content_length = value
                    .parse::<usize>()
                    .map_err(|_| reject(400, "Bad Request", "unparseable Content-Length"))?;
            }
            "transfer-encoding" => {
                return Err(reject(
                    501,
                    "Not Implemented",
                    "Transfer-Encoding is not supported; send Content-Length",
                ));
            }
            "expect" => {
                if value.eq_ignore_ascii_case("100-continue") {
                    expect_continue = true;
                }
            }
            _ => {}
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Err(reject(413, "Payload Too Large", "request body exceeds the size limit"));
    }
    if expect_continue && content_length > 0 {
        // curl sends Expect: 100-continue for >1 KiB bodies and waits
        // ~1 s for this interim response before giving up and sending
        // the body anyway — answer it so large panels upload promptly
        let _ = out.write_all(b"HTTP/1.1 100 Continue\r\n\r\n");
        let _ = out.flush();
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).map_err(|_| Reject::Gone)?;
    let body = String::from_utf8(body)
        .map_err(|_| reject(400, "Bad Request", "request body is not UTF-8"))?;
    Ok(HttpRequest { method, path, query, body })
}

/// Write a complete non-streaming response.
fn write_simple(out: &mut TcpStream, code: u16, reason: &str, content_type: &str, body: &str) {
    let _ = write!(
        out,
        "HTTP/1.1 {code} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len(),
    );
    let _ = out.flush();
}

/// Does the query string carry exactly `key=value`? No percent-decoding
/// — the only recognized pairs are plain ASCII literals.
fn query_has(query: &str, key: &str, value: &str) -> bool {
    query
        .split('&')
        .any(|pair| matches!(pair.split_once('='), Some((k, v)) if k == key && v == value))
}

/// Parse a (possibly empty) request body as one JSON object.
fn parse_body(body: &str) -> std::result::Result<Json, Reject> {
    if body.trim().is_empty() {
        return Ok(Json::Obj(Vec::new()));
    }
    protocol::parse_json(body).map_err(|e| reject(400, "Bad Request", &e.to_string()))
}

/// The single-line TCP frame equivalent of this HTTP request: the body
/// object with `"cmd"` (from the URL path) prepended — what a relay
/// tier ([`super::shard`]) forwards to a child server verbatim.
fn raw_frame(cmd: &str, body: &Json) -> String {
    let mut kvs: Vec<(String, Json)> = match body {
        Json::Obj(kvs) => kvs.iter().filter(|(k, _)| k != "cmd").cloned().collect(),
        _ => Vec::new(),
    };
    kvs.insert(0, ("cmd".to_string(), Json::Str(cmd.to_string())));
    Json::Obj(kvs).render()
}

/// Is this frame the last one a job will emit?
fn is_terminal(line: &str) -> bool {
    matches!(
        protocol::parse_json(line).ok().as_ref().and_then(|j| j.get("event")).and_then(Json::as_str),
        Some("result" | "error" | "canceled")
    )
}

/// Submit one job and stream its frames as SSE until the terminal one.
fn run_job(out: TcpStream, backend: &Arc<dyn Backend>, cmd: &str, body_text: &str) {
    let mut out = out;
    let body = match parse_body(body_text) {
        Ok(b) => b,
        Err(Reject::Status(code, reason, frame)) => {
            write_simple(&mut out, code, reason, "application/json", &(frame + "\n"));
            return;
        }
        Err(Reject::Gone) => return,
    };
    let spec = match protocol::request_from_parts(cmd, &body) {
        Ok(protocol::Request::Job(spec)) => spec,
        Ok(_) | Err(_) => {
            let msg = match protocol::request_from_parts(cmd, &body) {
                Err(e) => e.to_string(),
                Ok(_) => format!("{cmd:?} did not build a job request"),
            };
            let frame = protocol::frame_error(None, &msg);
            write_simple(&mut out, 400, "Bad Request", "application/json", &(frame + "\n"));
            return;
        }
    };
    let raw = raw_frame(cmd, &body);
    let client = backend.attach(&out);
    let _ = out.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n\
          Cache-Control: no-cache\r\nConnection: close\r\n\r\n",
    );
    let _ = out.flush();
    let done = Arc::new((Mutex::new(false), Condvar::new()));
    let done_tx = done.clone();
    let shared_out = Mutex::new(out);
    let sink: worker::Sink = Arc::new(move |line: &str| {
        if let Ok(mut s) = shared_out.lock() {
            let _ = s.write_all(b"data: ");
            let _ = s.write_all(line.as_bytes());
            let _ = s.write_all(b"\n\n");
            let _ = s.flush();
        }
        if is_terminal(line) {
            let (flag, cv) = &*done_tx;
            if let Ok(mut f) = flag.lock() {
                *f = true;
            }
            cv.notify_all();
        }
    });
    backend.submit(client, &raw, spec, &sink);
    // every submit path ends in a terminal frame (result, error or
    // canceled — including queue-closed and relay-lost errors), so this
    // wait terminates; the deadline is pure defense in depth
    let (flag, cv) = &*done;
    let deadline = std::time::Instant::now() + JOB_DEADLINE;
    let mut finished = flag.lock().expect("http job flag");
    while !*finished {
        let now = std::time::Instant::now();
        if now >= deadline {
            break;
        }
        let (guard, _timeout) =
            cv.wait_timeout(finished, deadline - now).expect("http job flag");
        finished = guard;
    }
    drop(finished);
    backend.detach(client);
}

/// The `"frames"` array of a `POST /watch` body: rows of numbers, in
/// stream order.
fn parse_watch_frames(body: &Json) -> std::result::Result<Vec<Vec<f64>>, Reject> {
    let Some(frames) = body.get("frames") else {
        return Ok(Vec::new());
    };
    let items = frames
        .as_arr()
        .ok_or_else(|| reject(400, "Bad Request", "\"frames\" must be an array of rows"))?;
    let mut rows = Vec::with_capacity(items.len());
    for item in items {
        let cells = item
            .as_arr()
            .ok_or_else(|| reject(400, "Bad Request", "each watch frame must be a number array"))?;
        let mut row = Vec::with_capacity(cells.len());
        for cell in cells {
            row.push(cell.as_f64().ok_or_else(|| {
                reject(400, "Bad Request", "each watch frame must be a number array")
            })?);
        }
        rows.push(row);
    }
    Ok(rows)
}

/// `POST /watch`: subscribe a watch stream, replay the body's
/// `"frames"` rows through it in order, end it, and stream everything
/// the job emits — `accepted`, per-frame `adjacency` events, the
/// summary `result` — as SSE until the terminal frame.
fn run_watch_replay(out: TcpStream, backend: &Arc<dyn Backend>, body_text: &str) {
    let mut out = out;
    let parsed = parse_body(body_text).and_then(|body| {
        let rows = parse_watch_frames(&body)?;
        Ok((body, rows))
    });
    let (body, rows) = match parsed {
        Ok(pair) => pair,
        Err(Reject::Status(code, reason, frame)) => {
            write_simple(&mut out, code, reason, "application/json", &(frame + "\n"));
            return;
        }
        Err(Reject::Gone) => return,
    };
    let spec = match protocol::request_from_parts("watch", &body) {
        Ok(protocol::Request::Job(spec)) => spec,
        Ok(_) => {
            let frame = protocol::frame_error(None, "\"watch\" did not build a job request");
            write_simple(&mut out, 400, "Bad Request", "application/json", &(frame + "\n"));
            return;
        }
        Err(e) => {
            let frame = protocol::frame_error(None, &e.to_string());
            write_simple(&mut out, 400, "Bad Request", "application/json", &(frame + "\n"));
            return;
        }
    };
    let id = spec.id.clone();
    let raw = raw_frame("watch", &body);
    let client = backend.attach(&out);
    let _ = out.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n\
          Cache-Control: no-cache\r\nConnection: close\r\n\r\n",
    );
    let _ = out.flush();
    let done = Arc::new((Mutex::new(false), Condvar::new()));
    let done_tx = done.clone();
    let shared_out = Mutex::new(out);
    let sink: worker::Sink = Arc::new(move |line: &str| {
        if let Ok(mut s) = shared_out.lock() {
            let _ = s.write_all(b"data: ");
            let _ = s.write_all(line.as_bytes());
            let _ = s.write_all(b"\n\n");
            let _ = s.flush();
        }
        if is_terminal(line) {
            let (flag, cv) = &*done_tx;
            if let Ok(mut f) = flag.lock() {
                *f = true;
            }
            cv.notify_all();
        }
    });
    backend.submit(client, &raw, spec, &sink);
    // replay the rows in order; a false feed means the stream already
    // reached its terminal frame (rejected, failed or drained), so the
    // remaining rows have nowhere to go
    for row in rows {
        if !backend.watch_feed(client, &id, WatchInput::Row(row)) {
            break;
        }
    }
    let _ = backend.watch_feed(client, &id, WatchInput::End);
    let (flag, cv) = &*done;
    let deadline = std::time::Instant::now() + JOB_DEADLINE;
    let mut finished = flag.lock().expect("http watch flag");
    while !*finished {
        let now = std::time::Instant::now();
        if now >= deadline {
            break;
        }
        let (guard, _timeout) =
            cv.wait_timeout(finished, deadline - now).expect("http watch flag");
        finished = guard;
    }
    drop(finished);
    backend.detach(client);
}

/// Answer a `cancel`/`shutdown` request with its single ack frame.
fn run_control(out: &mut TcpStream, backend: &Arc<dyn Backend>, cmd: &str, body_text: &str) {
    let body = match parse_body(body_text) {
        Ok(b) => b,
        Err(Reject::Status(code, reason, frame)) => {
            write_simple(out, code, reason, "application/json", &(frame + "\n"));
            return;
        }
        Err(Reject::Gone) => return,
    };
    match protocol::request_from_parts(cmd, &body) {
        Ok(protocol::Request::Cancel { id, target }) => {
            let known = backend.cancel(&target);
            let frame = protocol::frame_ack(id.as_deref(), "cancel", known);
            write_simple(out, 200, "OK", "application/json", &(frame + "\n"));
        }
        Ok(protocol::Request::Shutdown { id }) => {
            let frame = protocol::frame_ack(id.as_deref(), "shutdown", true);
            // ack first: request_shutdown may begin tearing the
            // listeners down immediately
            write_simple(out, 200, "OK", "application/json", &(frame + "\n"));
            backend.request_shutdown();
        }
        Ok(_) => {
            let frame = protocol::frame_error(None, &format!("{cmd:?} is not a control request"));
            write_simple(out, 400, "Bad Request", "application/json", &(frame + "\n"));
        }
        Err(e) => {
            let frame = protocol::frame_error(None, &e.to_string());
            write_simple(out, 400, "Bad Request", "application/json", &(frame + "\n"));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_frame_prepends_cmd_and_drops_an_embedded_one() {
        let body = protocol::parse_json(
            "{\"id\":\"a\",\"cmd\":\"status\",\"engine\":\"vectorized\"}",
        )
        .expect("parse");
        let raw = raw_frame("fit", &body);
        assert_eq!(raw, "{\"cmd\":\"fit\",\"id\":\"a\",\"engine\":\"vectorized\"}");
        // non-object bodies degrade to a bare command frame
        assert_eq!(raw_frame("fit", &Json::Null), "{\"cmd\":\"fit\"}");
    }

    #[test]
    fn watch_frames_parse_rows_and_reject_non_numeric() {
        let body = protocol::parse_json("{\"frames\":[[1,2],[3.5,-4]]}").expect("parse");
        assert_eq!(
            parse_watch_frames(&body).expect("rows"),
            vec![vec![1.0, 2.0], vec![3.5, -4.0]]
        );
        let none = protocol::parse_json("{\"id\":\"w\"}").expect("parse");
        assert!(parse_watch_frames(&none).expect("rows").is_empty());
        assert!(parse_watch_frames(&protocol::parse_json("{\"frames\":[[\"x\"]]}").unwrap())
            .is_err());
        assert!(parse_watch_frames(&protocol::parse_json("{\"frames\":42}").unwrap()).is_err());
    }

    #[test]
    fn query_flags_match_exact_pairs_only() {
        assert!(query_has("format=prometheus", "format", "prometheus"));
        assert!(query_has("a=1&format=prometheus&b=2", "format", "prometheus"));
        assert!(!query_has("", "format", "prometheus"));
        assert!(!query_has("format=json", "format", "prometheus"));
        assert!(!query_has("formats=prometheus", "format", "prometheus"));
        assert!(!query_has("format", "format", "prometheus"));
    }

    #[test]
    fn terminal_frame_detection_matches_the_three_terminal_events() {
        assert!(is_terminal(&protocol::frame_result(Some("a"), false, 1.0, "{\"k\":1}")));
        assert!(is_terminal(&protocol::frame_error(Some("a"), "boom")));
        assert!(is_terminal(&protocol::frame_canceled("a")));
        assert!(!is_terminal(&protocol::frame_accepted("a", 0)));
        assert!(!is_terminal(&protocol::frame_progress("a", "ordering", 1, 3)));
        assert!(!is_terminal("not json at all"));
    }
}
