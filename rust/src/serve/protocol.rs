//! The serve wire protocol: newline-delimited JSON frames over TCP,
//! parsed and emitted by a hand-rolled `std`-only JSON layer that
//! extends the crate's existing serialization surface
//! ([`Table::to_json`](crate::util::table::Table::to_json)'s
//! [`json_escape`](crate::util::table::json_escape) /
//! [`json_f64`](crate::util::table::json_f64) primitives), so the CLI
//! `--json` mode, the bench artifacts and the service speak one dialect.
//!
//! # Frames
//!
//! Every frame is one line of JSON. Requests carry a `cmd`; job requests
//! (`fit`, `bootstrap`, `varlingam`) also carry a client-chosen `id` the
//! streamed responses echo, and a panel — inline
//! (`"panel":{"rows":N,"cols":D,"data":[row-major f64…]}`) or as a
//! server-side CSV path (`"csv":"/path.csv"`). Examples:
//!
//! ```json
//! {"cmd":"fit","id":"a1","engine":"parallel:2","panel":{"rows":2,"cols":2,"data":[1,2,3,4]}}
//! {"cmd":"bootstrap","id":"b1","engine":"pruned","resamples":50,"seed":7,"panel":{...}}
//! {"cmd":"varlingam","id":"v1","lags":1,"csv":"/data/stocks.csv"}
//! {"cmd":"status"}
//! {"cmd":"metrics"}
//! {"cmd":"cancel","target":"a1"}
//! {"cmd":"shutdown"}
//! ```
//!
//! Responses stream: a job is acknowledged on receipt (the `accepted`
//! frame always precedes any frame the job itself emits; under
//! backpressure the connection then stalls until the queue has room),
//! emits progress while it runs, and terminates with exactly one
//! `result`, `error` or `canceled` frame:
//!
//! ```json
//! {"id":"a1","event":"accepted","queue_depth":1}
//! {"id":"a1","event":"progress","stage":"ordering","step":3,"total":31}
//! {"id":"a1","event":"result","cached":false,"elapsed_ms":12.5,"data":{"kind":"fit",...}}
//! {"id":"b1","event":"progress","stage":"bootstrap","step":17,"total":50}
//! {"id":"a1","event":"canceled"}
//! {"event":"error","message":"json: expected ',' or '}' at byte 17"}
//! ```
//!
//! Malformed frames never panic the server: the parser is total (depth-
//! limited recursive descent returning [`Error::Parse`]) and the
//! connection answers with an `error` frame, then resynchronizes at the
//! next newline.
//!
//! # The `watch` stream lifecycle
//!
//! `watch` opens a *long-lived* job: instead of one panel in / one
//! result out, the client subscribes a sliding-window streaming fit
//! (see [`crate::lingam::streaming`]) and then feeds it samples, one
//! `frame` request per tick, all carrying the subscription's `id`:
//!
//! ```json
//! {"cmd":"watch","id":"w1","dim":4,"window":256,"lags":1,"resync_every":64,"drift_tol":1e-8,"threshold":0.05,"engine":"parallel:2"}
//! {"cmd":"frame","id":"w1","row":[0.12,-0.3,1.7,0.02]}
//! {"cmd":"end","id":"w1"}
//! ```
//!
//! `lags:0` (the default) streams plain DirectLiNGAM over the window;
//! `lags ≥ 1` streams the lag-k VarLiNGAM re-estimate. An optional
//! `panel`/`csv` warms the window with seed rows before the first live
//! frame. The subscription is `accepted` like any job; each ingested
//! frame that lands on a full window answers with one `adjacency`
//! frame — the re-estimated model plus how it was produced
//! (`refit: "incremental" | "full"`, whether a moment `resync` ran, and
//! the window's current drift bound):
//!
//! ```json
//! {"id":"w1","event":"adjacency","frame":257,"refit":"incremental","resynced":false,"drift":1.2e-13,"elapsed_ms":0.4,"data":{"kind":"watch","order":[2,0,1,3],"b0":{...},"b_tau":[{...}]}}
//! ```
//!
//! The stream terminates with exactly one terminal frame, like every
//! job: a `result` whose data is the `watch_summary` (on `end` or a
//! graceful server drain), an `error` (bad frame mid-stream, dead
//! backend), or `canceled` (a `cancel` naming the subscription id).
//! Lifecycle: subscribe → `accepted` → {`frame` → `adjacency`}* →
//! [`resync` noted on the next adjacency] → `end` → `result`. Watch
//! jobs hold their client's queue lane while live and are excluded
//! from the worker's same-shape fusion window; shutdown drains them
//! gracefully (terminal summary, not an abrupt close).
//!
//! # HTTP ↔ JSON-lines payload equivalence
//!
//! The HTTP front ([`super::http`]) speaks the *same* protocol with the
//! command moved out of band: `POST /fit` (or `/bootstrap`,
//! `/varlingam`, `/cancel`, `/shutdown`) carries as its request body
//! exactly the JSON object a TCP request frame would be, minus the
//! `cmd` field, which is implied by the URL path. Both fronts funnel
//! through one builder — [`parse_request`] reads `cmd` out of the frame
//! and [`request_from_parts`] takes it from the path — so they accept
//! the same field grammar, apply the same defaults and validation, and
//! build identical [`JobSpec`]s. Responses reuse these frame builders
//! verbatim on both fronts: over TCP a frame is one line, over HTTP the
//! same line is one SSE event (`data: <frame>\n\n`) for job streams or
//! the whole `application/json` body for control requests — so the
//! `result` payload a client parses is byte-identical regardless of
//! which front carried it (integration-pinned by
//! `tests/serve_http.rs`).

use crate::coordinator::BootstrapResult;
use crate::linalg::Mat;
use crate::lingam::{SweepCounters, VarLingamFit};
use crate::util::table::{json_escape, json_f64};
use crate::util::{Error, Result};

// ---------------------------------------------------------------------
// JSON values: total parser + renderer.
// ---------------------------------------------------------------------

/// A parsed JSON value. Objects keep insertion order (a `Vec`, not a
/// map: frames are small and order-preserving round-trips are easier to
/// test).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Non-negative integer (rejects fractions and anything above 2⁵³,
    /// where f64 stops being exact).
    pub fn as_usize(&self) -> Option<usize> {
        let v = self.as_f64()?;
        if v >= 0.0 && v.fract() == 0.0 && v <= 9_007_199_254_740_992.0 {
            Some(v as usize)
        } else {
            None
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_usize().map(|v| v as u64)
    }

    /// Render back to compact JSON (non-finite numbers, which only a
    /// hand-constructed value can carry, become `null`).
    pub fn render(&self) -> String {
        match self {
            Json::Null => "null".to_string(),
            Json::Bool(b) => b.to_string(),
            Json::Num(v) => json_f64(*v),
            Json::Str(s) => format!("\"{}\"", json_escape(s)),
            Json::Arr(items) => {
                let body: Vec<String> = items.iter().map(Json::render).collect();
                format!("[{}]", body.join(","))
            }
            Json::Obj(kvs) => {
                let body: Vec<String> = kvs
                    .iter()
                    .map(|(k, v)| format!("\"{}\":{}", json_escape(k), v.render()))
                    .collect();
                format!("{{{}}}", body.join(","))
            }
        }
    }
}

/// Recursion guard: protocol frames are shallow; anything deeper than
/// this is hostile or broken, and recursing into it would risk the real
/// panic the parser exists to prevent (stack overflow).
const MAX_DEPTH: usize = 128;

/// Parse one complete JSON value (trailing content is an error). Total:
/// every input returns `Ok` or [`Error::Parse`], never a panic — pinned
/// by the fuzz-ish property suite in `tests/serve_protocol.rs`.
pub fn parse_json(s: &str) -> Result<Json> {
    let mut p = Parser { b: s.as_bytes(), i: 0 };
    let v = p.value(0)?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing content after value"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn err(&self, msg: &str) -> Error {
        Error::Parse(format!("json: {msg} at byte {}", self.i))
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        self.ws();
        match self.peek() {
            None => Err(self.err("unexpected end of input")),
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(_) => self.number(),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while matches!(self.peek(), Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')) {
            self.i += 1;
        }
        if start == self.i {
            return Err(self.err("expected a value"));
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("non-utf8 number"))?;
        let v: f64 = text.parse().map_err(|_| self.err("bad number"))?;
        if !v.is_finite() {
            return Err(self.err("non-finite number"));
        }
        Ok(Json::Num(v))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out: Vec<u8> = Vec::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => break,
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("unterminated escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push(b'"'),
                        b'\\' => out.push(b'\\'),
                        b'/' => out.push(b'/'),
                        b'b' => out.push(0x08),
                        b'f' => out.push(0x0c),
                        b'n' => out.push(b'\n'),
                        b'r' => out.push(b'\r'),
                        b't' => out.push(b'\t'),
                        b'u' => {
                            let ch = self.unicode_escape()?;
                            let mut buf = [0u8; 4];
                            out.extend_from_slice(ch.encode_utf8(&mut buf).as_bytes());
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                0x00..=0x1f => return Err(self.err("raw control character in string")),
                other => out.push(other),
            }
        }
        String::from_utf8(out).map_err(|_| self.err("invalid utf-8 in string"))
    }

    /// `\uXXXX`, including surrogate pairs; unpaired surrogates become
    /// U+FFFD rather than an error (lenient, but never panicking).
    fn unicode_escape(&mut self) -> Result<char> {
        let hi = self.hex4()?;
        let cp = if (0xD800..0xDC00).contains(&hi) {
            if self.b[self.i..].starts_with(b"\\u") {
                self.i += 2;
                let lo = self.hex4()?;
                if (0xDC00..0xE000).contains(&lo) {
                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                } else {
                    0xFFFD
                }
            } else {
                0xFFFD
            }
        } else if (0xDC00..0xE000).contains(&hi) {
            0xFFFD
        } else {
            hi
        };
        Ok(char::from_u32(cp).unwrap_or('\u{FFFD}'))
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v: u32 = 0;
        for _ in 0..4 {
            let c = self.peek().ok_or_else(|| self.err("truncated \\u escape"))?;
            self.i += 1;
            let digit =
                (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + digit;
        }
        Ok(v)
    }

    fn array(&mut self, depth: usize) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value(depth + 1)?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    break;
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
        Ok(Json::Arr(items))
    }

    fn object(&mut self, depth: usize) -> Result<Json> {
        self.expect(b'{')?;
        let mut kvs = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            let value = self.value(depth + 1)?;
            kvs.push((key, value));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    break;
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
        Ok(Json::Obj(kvs))
    }
}

// ---------------------------------------------------------------------
// Requests.
// ---------------------------------------------------------------------

/// Where a job's data panel comes from.
#[derive(Clone, Debug)]
pub enum PanelSource {
    /// Row-major values shipped in the frame.
    Inline(Mat),
    /// A CSV path resolved on the server's filesystem (loaded by the
    /// worker, so a slow disk never stalls the connection reader).
    Csv(String),
}

/// What a job computes.
#[derive(Clone, Debug)]
pub enum JobKind {
    /// DirectLiNGAM fit: causal order + pruned adjacency.
    Fit,
    /// Bootstrap edge-confidence estimation.
    Bootstrap { resamples: usize, seed: u64, threshold: f64, workers: usize },
    /// VarLiNGAM on a time-series panel.
    Var { lags: usize },
    /// A long-lived streaming subscription: sliding-window re-estimation
    /// over frames fed by `frame` requests (`lags == 0` ⇒ plain
    /// DirectLiNGAM, `lags ≥ 1` ⇒ lag-k VarLiNGAM). The job's panel, if
    /// any, only warms the window.
    Watch {
        dim: usize,
        window: usize,
        lags: usize,
        resync_every: usize,
        drift_tol: f64,
        threshold: f64,
    },
}

/// A queued unit of work (the protocol half; the runtime half wraps it
/// with a cancel flag and a reply sink in [`super::worker::Job`]).
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// Client-chosen id echoed on every response frame.
    pub id: String,
    pub panel: PanelSource,
    /// Raw engine spec string (parsed/normalized by the worker).
    pub engine: String,
    pub kind: JobKind,
    /// 128-bit trace id, minted server-side at submit (0 until then —
    /// the parser never sets it; clients do not choose trace ids).
    pub trace: u128,
}

/// A parsed request frame.
#[derive(Clone, Debug)]
pub enum Request {
    Job(JobSpec),
    /// One streamed sample for a live `watch` subscription.
    Frame { id: String, row: Vec<f64> },
    /// Graceful end of a `watch` stream (flush the terminal summary).
    End { id: String },
    Status { id: Option<String> },
    Metrics { id: Option<String> },
    /// Look up a completed job's recorded trace by trace id (32 hex
    /// chars) or by job id (latest trace under that id wins).
    Trace { id: Option<String>, target: String },
    Cancel { id: Option<String>, target: String },
    Shutdown { id: Option<String> },
}

/// Parse one request line. Every failure is a recoverable
/// [`Error::Parse`]/[`Error::Shape`] the connection reports as an
/// `error` frame.
pub fn parse_request(line: &str) -> Result<Request> {
    let j = parse_json(line)?;
    let cmd = j
        .get("cmd")
        .and_then(Json::as_str)
        .ok_or_else(|| Error::Parse("frame missing string \"cmd\"".into()))?
        .to_string();
    request_from_parts(&cmd, &j)
}

/// Build a request from a command name plus its JSON body — the one
/// builder behind both wire fronts. The TCP front reads `cmd` out of
/// the frame itself ([`parse_request`]); the HTTP front derives it from
/// the URL path (`POST /fit` ⇒ `"fit"`) and passes the request body
/// unchanged, so the two fronts accept the same field grammar and build
/// identical [`JobSpec`]s (see the module docs on payload equivalence).
/// A `cmd` field inside `j` is ignored in favor of the argument.
pub fn request_from_parts(cmd: &str, j: &Json) -> Result<Request> {
    let id = j.get("id").and_then(Json::as_str).map(str::to_string);
    let job = |kind: JobKind| -> Result<Request> {
        let id = id
            .clone()
            .ok_or_else(|| Error::Parse(format!("{cmd:?} frame missing string \"id\"")))?;
        Ok(Request::Job(JobSpec {
            id,
            panel: parse_panel_source(j)?,
            engine: j
                .get("engine")
                .and_then(Json::as_str)
                .unwrap_or("parallel")
                .to_string(),
            kind,
            trace: 0,
        }))
    };
    match cmd {
        "fit" => job(JobKind::Fit),
        "bootstrap" => {
            let resamples = field_usize(j, "resamples", 50)?;
            if resamples == 0 {
                return Err(Error::Parse("\"resamples\" must be ≥ 1".into()));
            }
            let seed = j
                .get("seed")
                .map(|v| v.as_u64().ok_or_else(|| bad_field("seed")))
                .transpose()?
                .unwrap_or(0);
            let threshold = j
                .get("threshold")
                .map(|v| v.as_f64().ok_or_else(|| bad_field("threshold")))
                .transpose()?
                .unwrap_or(0.05);
            let workers = field_usize(j, "workers", 1)?;
            job(JobKind::Bootstrap { resamples, seed, threshold, workers })
        }
        "varlingam" | "var" => {
            let lags = field_usize(j, "lags", 1)?;
            if lags == 0 {
                return Err(Error::Parse("\"lags\" must be ≥ 1".into()));
            }
            job(JobKind::Var { lags })
        }
        "watch" => {
            let id = id
                .clone()
                .ok_or_else(|| Error::Parse("\"watch\" frame missing string \"id\"".into()))?;
            let dim = field_usize(j, "dim", 0)?;
            if dim < 2 {
                return Err(Error::Parse("\"watch\" needs integer \"dim\" ≥ 2".into()));
            }
            let window = field_usize(j, "window", 256)?;
            if window < 8 {
                return Err(Error::Parse("\"window\" must be ≥ 8".into()));
            }
            let lags = field_usize(j, "lags", 0)?;
            let resync_every = field_usize(j, "resync_every", 64)?;
            let drift_tol = j
                .get("drift_tol")
                .map(|v| v.as_f64().ok_or_else(|| bad_field("drift_tol")))
                .transpose()?
                .unwrap_or(1e-8);
            let threshold = j
                .get("threshold")
                .map(|v| v.as_f64().ok_or_else(|| bad_field("threshold")))
                .transpose()?
                .unwrap_or(0.05);
            // the panel is optional here (it only warms the window);
            // absent, an empty sentinel keeps JobSpec uniform
            let panel = if j.get("panel").is_some() || j.get("csv").is_some() {
                parse_panel_source(j)?
            } else {
                PanelSource::Inline(Mat::zeros(0, dim))
            };
            Ok(Request::Job(JobSpec {
                id,
                panel,
                engine: j
                    .get("engine")
                    .and_then(Json::as_str)
                    .unwrap_or("parallel")
                    .to_string(),
                kind: JobKind::Watch { dim, window, lags, resync_every, drift_tol, threshold },
                trace: 0,
            }))
        }
        "frame" => {
            let id = id
                .clone()
                .ok_or_else(|| Error::Parse("\"frame\" frame missing string \"id\"".into()))?;
            let row = j
                .get("row")
                .and_then(Json::as_arr)
                .ok_or_else(|| Error::Parse("\"frame\" needs number array \"row\"".into()))?
                .iter()
                .map(|v| {
                    v.as_f64().ok_or_else(|| Error::Parse("\"row\" must be numbers".into()))
                })
                .collect::<Result<Vec<f64>>>()?;
            if row.is_empty() {
                return Err(Error::Parse("\"row\" must be non-empty".into()));
            }
            Ok(Request::Frame { id, row })
        }
        "end" => {
            let id = id
                .clone()
                .ok_or_else(|| Error::Parse("\"end\" frame missing string \"id\"".into()))?;
            Ok(Request::End { id })
        }
        "status" => Ok(Request::Status { id }),
        "metrics" => Ok(Request::Metrics { id }),
        "trace" => {
            let target = j
                .get("target")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::Parse("trace frame missing string \"target\"".into()))?
                .to_string();
            Ok(Request::Trace { id, target })
        }
        "cancel" => {
            let target = j
                .get("target")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::Parse("cancel frame missing string \"target\"".into()))?
                .to_string();
            Ok(Request::Cancel { id, target })
        }
        "shutdown" => Ok(Request::Shutdown { id }),
        other => Err(Error::Parse(format!(
            "unknown cmd {other:?} \
             (fit|bootstrap|varlingam|watch|frame|end|status|metrics|trace|cancel|shutdown)"
        ))),
    }
}

fn bad_field(name: &str) -> Error {
    Error::Parse(format!("field {name:?} has the wrong type"))
}

fn field_usize(j: &Json, name: &str, default: usize) -> Result<usize> {
    match j.get(name) {
        None => Ok(default),
        Some(v) => v.as_usize().ok_or_else(|| bad_field(name)),
    }
}

fn parse_panel_source(j: &Json) -> Result<PanelSource> {
    if let Some(path) = j.get("csv").and_then(Json::as_str) {
        return Ok(PanelSource::Csv(path.to_string()));
    }
    let p = j
        .get("panel")
        .ok_or_else(|| Error::Parse("job frame needs \"panel\" or \"csv\"".into()))?;
    Ok(PanelSource::Inline(parse_mat(p)?))
}

/// Decode `{"rows":N,"cols":D,"data":[...]}` into a [`Mat`]. Shared by
/// the server (inline panels) and the round-trip tests (adjacency
/// matrices coming back out of result frames).
pub fn parse_mat(j: &Json) -> Result<Mat> {
    let rows = j
        .get("rows")
        .and_then(Json::as_usize)
        .ok_or_else(|| Error::Parse("matrix needs integer \"rows\"".into()))?;
    let cols = j
        .get("cols")
        .and_then(Json::as_usize)
        .ok_or_else(|| Error::Parse("matrix needs integer \"cols\"".into()))?;
    let data = j
        .get("data")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::Parse("matrix needs array \"data\"".into()))?;
    if rows.checked_mul(cols) != Some(data.len()) {
        return Err(Error::Shape(format!(
            "matrix data length {} != rows {rows} × cols {cols}",
            data.len()
        )));
    }
    let flat: Result<Vec<f64>> = data
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| Error::Parse("matrix data must be numbers".into())))
        .collect();
    Mat::from_vec(rows, cols, flat?)
}

// ---------------------------------------------------------------------
// Frame builders (responses and client-side requests).
// ---------------------------------------------------------------------

fn id_prefix(id: Option<&str>) -> String {
    match id {
        Some(id) => format!("\"id\":\"{}\",", json_escape(id)),
        None => String::new(),
    }
}

pub fn frame_accepted(id: &str, queue_depth: usize) -> String {
    format!(
        "{{\"id\":\"{}\",\"event\":\"accepted\",\"queue_depth\":{queue_depth}}}",
        json_escape(id)
    )
}

pub fn frame_progress(id: &str, stage: &str, step: usize, total: usize) -> String {
    format!(
        "{{\"id\":\"{}\",\"event\":\"progress\",\"stage\":\"{}\",\"step\":{step},\
         \"total\":{total}}}",
        json_escape(id),
        json_escape(stage)
    )
}

pub fn frame_result(id: Option<&str>, cached: bool, elapsed_ms: f64, data: &str) -> String {
    format!(
        "{{{}\"event\":\"result\",\"cached\":{cached},\"elapsed_ms\":{},\"data\":{data}}}",
        id_prefix(id),
        json_f64(elapsed_ms)
    )
}

/// [`frame_result`] with an optional `"timing"` object — the compact
/// per-span breakdown the trace layer attaches to terminal result
/// frames (`timing` must already be rendered JSON, e.g.
/// [`TraceRecord::timing_json`](crate::obs::trace::TraceRecord::timing_json)).
/// `None` renders byte-identically to [`frame_result`].
pub fn frame_result_traced(
    id: Option<&str>,
    cached: bool,
    elapsed_ms: f64,
    data: &str,
    timing: Option<&str>,
) -> String {
    match timing {
        None => frame_result(id, cached, elapsed_ms, data),
        Some(t) => format!(
            "{{{}\"event\":\"result\",\"cached\":{cached},\"elapsed_ms\":{},\"timing\":{t},\
             \"data\":{data}}}",
            id_prefix(id),
            json_f64(elapsed_ms)
        ),
    }
}

pub fn frame_error(id: Option<&str>, message: &str) -> String {
    format!(
        "{{{}\"event\":\"error\",\"message\":\"{}\"}}",
        id_prefix(id),
        json_escape(message)
    )
}

pub fn frame_canceled(id: &str) -> String {
    format!("{{\"id\":\"{}\",\"event\":\"canceled\"}}", json_escape(id))
}

/// Acknowledgement for the control commands (`cancel`, `shutdown`).
pub fn frame_ack(id: Option<&str>, what: &str, ok: bool) -> String {
    format!(
        "{{{}\"event\":\"ack\",\"of\":\"{}\",\"ok\":{ok}}}",
        id_prefix(id),
        json_escape(what)
    )
}

/// `{"rows":..,"cols":..,"data":[...]}` — row-major, shortest-roundtrip
/// float tokens.
pub fn mat_json(m: &Mat) -> String {
    let mut data = String::with_capacity(16 * m.rows() * m.cols() + 32);
    for (k, v) in m.as_slice().iter().enumerate() {
        if k > 0 {
            data.push(',');
        }
        data.push_str(&json_f64(*v));
    }
    format!("{{\"rows\":{},\"cols\":{},\"data\":[{}]}}", m.rows(), m.cols(), data)
}

fn usize_array(v: &[usize]) -> String {
    let body: Vec<String> = v.iter().map(|x| x.to_string()).collect();
    format!("[{}]", body.join(","))
}

fn sweep_json(c: &SweepCounters) -> String {
    format!(
        "{{\"pairs_total\":{},\"pairs_visited\":{},\"pairs_skipped\":{},\
         \"candidates_pruned\":{},\"elements_touched\":{}}}",
        c.pairs_total, c.pairs_visited, c.pairs_skipped, c.candidates_pruned, c.elements_touched
    )
}

/// The `data` payload of a fit result. `counters` are the session's
/// sweep instrumentation (all-zero when the path is not instrumented —
/// the stateless shim, the device session, the non-session CLI fit).
pub fn fit_data(
    engine: &str,
    order: &[usize],
    adjacency: &Mat,
    counters: &SweepCounters,
) -> String {
    format!(
        "{{\"kind\":\"fit\",\"engine\":\"{}\",\"order\":{},\"adjacency\":{},\"sweep\":{}}}",
        json_escape(engine),
        usize_array(order),
        mat_json(adjacency),
        sweep_json(counters)
    )
}

/// The `data` payload of a bootstrap result: edges at or above the
/// requested stability threshold, sorted by probability.
pub fn bootstrap_data(engine: &str, r: &BootstrapResult, threshold: f64) -> String {
    let edges: Vec<String> = r
        .stable_edges(threshold)
        .into_iter()
        .map(|(from, to, p, w)| {
            format!(
                "{{\"from\":{from},\"to\":{to},\"prob\":{},\"weight\":{}}}",
                json_f64(p),
                json_f64(w)
            )
        })
        .collect();
    format!(
        "{{\"kind\":\"bootstrap\",\"engine\":\"{}\",\"resamples\":{},\"threshold\":{},\
         \"stable_edges\":[{}]}}",
        json_escape(engine),
        r.resamples,
        json_f64(threshold),
        edges.join(",")
    )
}

/// The `data` payload of a VarLiNGAM result.
pub fn var_data(engine: &str, fit: &VarLingamFit) -> String {
    let lags: Vec<String> = fit.b_tau.iter().map(mat_json).collect();
    format!(
        "{{\"kind\":\"varlingam\",\"engine\":\"{}\",\"order\":{},\"b0\":{},\"b_tau\":[{}]}}",
        json_escape(engine),
        usize_array(&fit.order),
        mat_json(&fit.b0),
        lags.join(",")
    )
}

/// A `watch` stream's per-frame re-estimate: one `adjacency` event per
/// ingested sample once the window is full. `refit` is
/// [`RefitKind::as_str`](crate::lingam::streaming::RefitKind::as_str);
/// `drift` is the window's relative drift bound after the frame.
pub fn frame_adjacency(
    id: &str,
    frame: u64,
    refit: &str,
    resynced: bool,
    drift: f64,
    elapsed_ms: f64,
    data: &str,
) -> String {
    format!(
        "{{\"id\":\"{}\",\"event\":\"adjacency\",\"frame\":{frame},\"refit\":\"{}\",\
         \"resynced\":{resynced},\"drift\":{},\"elapsed_ms\":{},\"data\":{data}}}",
        json_escape(id),
        json_escape(refit),
        json_f64(drift),
        json_f64(elapsed_ms)
    )
}

/// The `data` payload of an `adjacency` frame: held order, B̂₀, and the
/// lag matrices (empty for a plain `lags:0` stream).
pub fn watch_update_data(order: &[usize], b0: &Mat, b_tau: &[Mat]) -> String {
    let lags: Vec<String> = b_tau.iter().map(mat_json).collect();
    format!(
        "{{\"kind\":\"watch\",\"order\":{},\"b0\":{},\"b_tau\":[{}]}}",
        usize_array(order),
        mat_json(b0),
        lags.join(",")
    )
}

/// The `data` payload of a watch stream's terminal `result` frame.
pub fn watch_summary_data(
    engine: &str,
    frames: u64,
    refits_incremental: u64,
    refits_full: u64,
    resyncs: u64,
) -> String {
    format!(
        "{{\"kind\":\"watch_summary\",\"engine\":\"{}\",\"frames\":{frames},\
         \"refits_incremental\":{refits_incremental},\"refits_full\":{refits_full},\
         \"resyncs\":{resyncs}}}",
        json_escape(engine)
    )
}

/// Client-side: subscribe a `watch` stream (`lags == 0` ⇒ plain
/// DirectLiNGAM over the window).
pub fn watch_request(
    id: &str,
    engine: &str,
    dim: usize,
    window: usize,
    lags: usize,
    resync_every: usize,
    drift_tol: f64,
    threshold: f64,
) -> String {
    format!(
        "{{\"cmd\":\"watch\",\"id\":\"{}\",\"engine\":\"{}\",\"dim\":{dim},\"window\":{window},\
         \"lags\":{lags},\"resync_every\":{resync_every},\"drift_tol\":{},\"threshold\":{}}}",
        json_escape(id),
        json_escape(engine),
        json_f64(drift_tol),
        json_f64(threshold)
    )
}

/// Client-side: one streamed sample for a live watch subscription.
pub fn watch_frame_request(id: &str, row: &[f64]) -> String {
    let body: Vec<String> = row.iter().map(|v| json_f64(*v)).collect();
    format!("{{\"cmd\":\"frame\",\"id\":\"{}\",\"row\":[{}]}}", json_escape(id), body.join(","))
}

/// Client-side: gracefully end a watch stream.
pub fn watch_end_request(id: &str) -> String {
    format!("{{\"cmd\":\"end\",\"id\":\"{}\"}}", json_escape(id))
}

/// Client-side: a `fit` request with an inline panel.
pub fn fit_request(id: &str, engine: &str, panel: &Mat) -> String {
    format!(
        "{{\"cmd\":\"fit\",\"id\":\"{}\",\"engine\":\"{}\",\"panel\":{}}}",
        json_escape(id),
        json_escape(engine),
        mat_json(panel)
    )
}

/// Client-side: a `fit` request naming a server-side CSV.
pub fn csv_fit_request(id: &str, engine: &str, path: &str) -> String {
    format!(
        "{{\"cmd\":\"fit\",\"id\":\"{}\",\"engine\":\"{}\",\"csv\":\"{}\"}}",
        json_escape(id),
        json_escape(engine),
        json_escape(path)
    )
}

/// Client-side: a `bootstrap` request with an inline panel.
pub fn bootstrap_request(
    id: &str,
    engine: &str,
    panel: &Mat,
    resamples: usize,
    seed: u64,
    threshold: f64,
) -> String {
    format!(
        "{{\"cmd\":\"bootstrap\",\"id\":\"{}\",\"engine\":\"{}\",\"resamples\":{resamples},\
         \"seed\":{seed},\"threshold\":{},\"panel\":{}}}",
        json_escape(id),
        json_escape(engine),
        json_f64(threshold),
        mat_json(panel)
    )
}

/// Client-side: a `varlingam` request with an inline panel.
pub fn var_request(id: &str, engine: &str, panel: &Mat, lags: usize) -> String {
    format!(
        "{{\"cmd\":\"varlingam\",\"id\":\"{}\",\"engine\":\"{}\",\"lags\":{lags},\"panel\":{}}}",
        json_escape(id),
        json_escape(engine),
        mat_json(panel)
    )
}

/// Client-side: a bare control request (`status`, `metrics`,
/// `shutdown`).
pub fn control_request(cmd: &str) -> String {
    format!("{{\"cmd\":\"{}\"}}", json_escape(cmd))
}

/// Client-side: cancel a submitted job by id. Lookup is server-wide, so
/// a one-shot connection (`alingam client cancel`) can cancel a job
/// submitted on another connection; every live job under that id is
/// flagged.
pub fn cancel_request(target: &str) -> String {
    format!("{{\"cmd\":\"cancel\",\"target\":\"{}\"}}", json_escape(target))
}

/// Client-side: look up a completed job's trace by trace id (32 hex
/// chars) or job id.
pub fn trace_request(target: &str) -> String {
    format!("{{\"cmd\":\"trace\",\"target\":\"{}\"}}", json_escape(target))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_values_parse() {
        assert_eq!(parse_json("null").unwrap(), Json::Null);
        assert_eq!(parse_json(" true ").unwrap(), Json::Bool(true));
        assert_eq!(parse_json("false").unwrap(), Json::Bool(false));
        assert_eq!(parse_json("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse_json("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
        assert_eq!(parse_json("\"\\u00e9\"").unwrap(), Json::Str("é".into()));
        // surrogate pair
        assert_eq!(parse_json("\"\\ud83d\\ude00\"").unwrap(), Json::Str("😀".into()));
    }

    #[test]
    fn containers_parse_and_render_roundtrip() {
        let src = "{\"a\":[1,2.5,\"x\"],\"b\":{\"c\":null,\"d\":false}}";
        let v = parse_json(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.render(), src);
        // render → parse is the identity on parsed values
        assert_eq!(parse_json(&v.render()).unwrap(), v);
    }

    #[test]
    fn malformed_inputs_error_cleanly() {
        for bad in [
            "",
            "{",
            "}",
            "[1,",
            "{\"a\"}",
            "{\"a\":}",
            "\"unterminated",
            "\"bad\\escape\"",
            "nul",
            "1.2.3",
            "inf",
            "NaN",
            "[1] trailing",
            "{\"a\":1,}x",
            "\"\\u12\"",
            "\u{1}",
        ] {
            assert!(parse_json(bad).is_err(), "accepted malformed {bad:?}");
        }
        // deep nesting hits the depth guard, not the stack
        let deep = "[".repeat(10_000);
        assert!(parse_json(&deep).is_err());
    }

    #[test]
    fn mat_roundtrip() {
        let m = Mat::from_rows(&[&[1.0, -2.5, 0.0], &[3.25, 4.0, 1e-9]]);
        let j = parse_json(&mat_json(&m)).unwrap();
        let back = parse_mat(&j).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn mat_rejects_bad_shapes() {
        assert!(parse_mat(&parse_json("{\"rows\":2,\"cols\":2,\"data\":[1,2,3]}").unwrap())
            .is_err());
        assert!(parse_mat(&parse_json("{\"rows\":1,\"cols\":1}").unwrap()).is_err());
        assert!(
            parse_mat(&parse_json("{\"rows\":1,\"cols\":2,\"data\":[1,\"x\"]}").unwrap()).is_err()
        );
    }

    #[test]
    fn requests_parse() {
        let m = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        match parse_request(&fit_request("j1", "parallel:2", &m)).unwrap() {
            Request::Job(spec) => {
                assert_eq!(spec.id, "j1");
                assert_eq!(spec.engine, "parallel:2");
                assert!(matches!(spec.kind, JobKind::Fit));
                match spec.panel {
                    PanelSource::Inline(p) => assert_eq!(p, m),
                    other => panic!("unexpected panel source {other:?}"),
                }
            }
            other => panic!("unexpected request {other:?}"),
        }
        match parse_request(&bootstrap_request("b", "vec", &m, 20, 7, 0.25)).unwrap() {
            Request::Job(spec) => match spec.kind {
                JobKind::Bootstrap { resamples, seed, threshold, workers } => {
                    assert_eq!((resamples, seed, workers), (20, 7, 1));
                    assert!((threshold - 0.25).abs() < 1e-12);
                }
                other => panic!("unexpected kind {other:?}"),
            },
            other => panic!("unexpected request {other:?}"),
        }
        match parse_request(&var_request("v", "seq", &m, 2)).unwrap() {
            Request::Job(spec) => assert!(matches!(spec.kind, JobKind::Var { lags: 2 })),
            other => panic!("unexpected request {other:?}"),
        }
        assert!(matches!(
            parse_request(&control_request("status")).unwrap(),
            Request::Status { .. }
        ));
        assert!(matches!(
            parse_request(&control_request("metrics")).unwrap(),
            Request::Metrics { .. }
        ));
        assert!(matches!(
            parse_request(&control_request("shutdown")).unwrap(),
            Request::Shutdown { .. }
        ));
        match parse_request(&cancel_request("j1")).unwrap() {
            Request::Cancel { target, .. } => assert_eq!(target, "j1"),
            other => panic!("unexpected request {other:?}"),
        }
        match parse_request(&trace_request("deadbeef")).unwrap() {
            Request::Trace { target, .. } => assert_eq!(target, "deadbeef"),
            other => panic!("unexpected request {other:?}"),
        }
        assert!(parse_request("{\"cmd\":\"trace\"}").is_err(), "trace needs a target");
        match parse_request(&csv_fit_request("c", "par", "/tmp/x.csv")).unwrap() {
            Request::Job(spec) => {
                assert!(matches!(spec.panel, PanelSource::Csv(p) if p == "/tmp/x.csv"))
            }
            other => panic!("unexpected request {other:?}"),
        }
    }

    #[test]
    fn watch_requests_parse() {
        let sub = watch_request("w1", "parallel:2", 4, 128, 1, 32, 1e-9, 0.1);
        match parse_request(&sub).unwrap() {
            Request::Job(spec) => {
                assert_eq!(spec.id, "w1");
                assert_eq!(spec.engine, "parallel:2");
                match spec.kind {
                    JobKind::Watch { dim, window, lags, resync_every, drift_tol, threshold } => {
                        assert_eq!((dim, window, lags, resync_every), (4, 128, 1, 32));
                        assert!((drift_tol - 1e-9).abs() < 1e-24);
                        assert!((threshold - 0.1).abs() < 1e-12);
                    }
                    other => panic!("unexpected kind {other:?}"),
                }
                // no seed panel ⇒ the empty sentinel
                match spec.panel {
                    PanelSource::Inline(p) => assert_eq!((p.rows(), p.cols()), (0, 4)),
                    other => panic!("unexpected panel source {other:?}"),
                }
            }
            other => panic!("unexpected request {other:?}"),
        }
        // defaults: plain stream, window 256, cadence 64
        let bare = parse_request("{\"cmd\":\"watch\",\"id\":\"w\",\"dim\":3}").unwrap();
        match bare {
            Request::Job(spec) => match spec.kind {
                JobKind::Watch { dim, window, lags, resync_every, .. } => {
                    assert_eq!((dim, window, lags, resync_every), (3, 256, 0, 64));
                }
                other => panic!("unexpected kind {other:?}"),
            },
            other => panic!("unexpected request {other:?}"),
        }
        match parse_request(&watch_frame_request("w1", &[0.5, -1.25, 3.0])).unwrap() {
            Request::Frame { id, row } => {
                assert_eq!(id, "w1");
                assert_eq!(row, vec![0.5, -1.25, 3.0]);
            }
            other => panic!("unexpected request {other:?}"),
        }
        match parse_request(&watch_end_request("w1")).unwrap() {
            Request::End { id } => assert_eq!(id, "w1"),
            other => panic!("unexpected request {other:?}"),
        }
        // validation: dim, window, row, missing ids
        assert!(parse_request("{\"cmd\":\"watch\",\"id\":\"w\"}").is_err());
        assert!(parse_request("{\"cmd\":\"watch\",\"id\":\"w\",\"dim\":1}").is_err());
        assert!(
            parse_request("{\"cmd\":\"watch\",\"id\":\"w\",\"dim\":3,\"window\":4}").is_err()
        );
        assert!(parse_request("{\"cmd\":\"watch\",\"dim\":3}").is_err());
        assert!(parse_request("{\"cmd\":\"frame\",\"id\":\"w\"}").is_err());
        assert!(parse_request("{\"cmd\":\"frame\",\"id\":\"w\",\"row\":[]}").is_err());
        assert!(parse_request("{\"cmd\":\"frame\",\"id\":\"w\",\"row\":[1,\"x\"]}").is_err());
        assert!(parse_request("{\"cmd\":\"end\"}").is_err());
    }

    #[test]
    fn adjacency_and_summary_frames_roundtrip() {
        let b0 = Mat::from_rows(&[&[0.0, 0.0], &[1.5, 0.0]]);
        let b1 = Mat::from_rows(&[&[0.2, 0.0], &[0.0, -0.4]]);
        let data = watch_update_data(&[0, 1], &b0, std::slice::from_ref(&b1));
        let frame = frame_adjacency("w1", 257, "incremental", false, 1.2e-13, 0.4, &data);
        assert!(!frame.contains('\n'));
        let j = parse_json(&frame).unwrap();
        assert_eq!(j.get("event").and_then(Json::as_str), Some("adjacency"));
        assert_eq!(j.get("frame").and_then(Json::as_u64), Some(257));
        assert_eq!(j.get("refit").and_then(Json::as_str), Some("incremental"));
        assert_eq!(j.get("resynced").and_then(Json::as_bool), Some(false));
        let d = j.get("data").unwrap();
        assert_eq!(d.get("kind").and_then(Json::as_str), Some("watch"));
        assert_eq!(parse_mat(d.get("b0").unwrap()).unwrap(), b0);
        let taus = d.get("b_tau").and_then(Json::as_arr).unwrap();
        assert_eq!(parse_mat(&taus[0]).unwrap(), b1);
        let s = parse_json(&frame_result(
            Some("w1"),
            false,
            9.0,
            &watch_summary_data("parallel", 300, 290, 6, 5),
        ))
        .unwrap();
        let sd = s.get("data").unwrap();
        assert_eq!(sd.get("kind").and_then(Json::as_str), Some("watch_summary"));
        assert_eq!(sd.get("frames").and_then(Json::as_u64), Some(300));
        assert_eq!(sd.get("refits_incremental").and_then(Json::as_u64), Some(290));
        assert_eq!(sd.get("refits_full").and_then(Json::as_u64), Some(6));
        assert_eq!(sd.get("resyncs").and_then(Json::as_u64), Some(5));
    }

    #[test]
    fn request_validation_errors() {
        // job frames need an id and a panel
        assert!(parse_request("{\"cmd\":\"fit\"}").is_err());
        assert!(parse_request("{\"cmd\":\"fit\",\"id\":\"a\"}").is_err());
        let boot0 = "{\"cmd\":\"bootstrap\",\"id\":\"a\",\"resamples\":0,\"csv\":\"x\"}";
        assert!(parse_request(boot0).is_err());
        let var0 = "{\"cmd\":\"varlingam\",\"id\":\"a\",\"lags\":0,\"csv\":\"x\"}";
        assert!(parse_request(var0).is_err());
        assert!(parse_request("{\"cmd\":\"cancel\"}").is_err());
        assert!(parse_request("{\"cmd\":\"nope\"}").is_err());
        assert!(parse_request("[]").is_err());
    }

    #[test]
    fn fit_result_roundtrips_through_the_parser() {
        // the one serialization surface: what the CLI --json mode and
        // the serve result frames emit must parse back to the same
        // order/adjacency (the satellite's round-trip requirement)
        let order = vec![2usize, 0, 1];
        let adj = Mat::from_rows(&[&[0.0, 0.0, 1.25], &[-0.5, 0.0, 0.75], &[0.0, 0.0, 0.0]]);
        let mut counters = SweepCounters::default();
        counters.record_exact(3, 100);
        let payload = fit_data("vectorized", &order, &adj, &counters);
        let frame = frame_result(Some("x1"), false, 12.5, &payload);
        let j = parse_json(&frame).unwrap();
        assert_eq!(j.get("id").and_then(Json::as_str), Some("x1"));
        assert_eq!(j.get("event").and_then(Json::as_str), Some("result"));
        assert_eq!(j.get("cached").and_then(Json::as_bool), Some(false));
        let data = j.get("data").unwrap();
        assert_eq!(data.get("kind").and_then(Json::as_str), Some("fit"));
        assert_eq!(data.get("engine").and_then(Json::as_str), Some("vectorized"));
        let got_order: Vec<usize> = data
            .get("order")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        assert_eq!(got_order, order);
        let got_adj = parse_mat(data.get("adjacency").unwrap()).unwrap();
        assert_eq!(got_adj, adj);
        let sweep = data.get("sweep").unwrap();
        assert_eq!(sweep.get("pairs_total").and_then(Json::as_u64), Some(3));
        assert_eq!(sweep.get("elements_touched").and_then(Json::as_u64), Some(300));
    }

    #[test]
    fn http_body_and_tcp_frame_build_identical_jobspecs() {
        // the equivalence contract: a TCP frame parsed whole and the
        // same object handed to request_from_parts with the cmd taken
        // from a URL path must build the same JobSpec (the body's own
        // cmd field, when present, is ignored in favor of the path)
        let m = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let line = bootstrap_request("j1", "pruned:2", &m, 20, 7, 0.25);
        let body = parse_json(&line).unwrap();
        let (tcp, http) = match (
            parse_request(&line).unwrap(),
            request_from_parts("bootstrap", &body).unwrap(),
        ) {
            (Request::Job(a), Request::Job(b)) => (a, b),
            other => panic!("unexpected requests {other:?}"),
        };
        assert_eq!(tcp.id, http.id);
        assert_eq!(tcp.engine, http.engine);
        match (&tcp.kind, &http.kind) {
            (
                JobKind::Bootstrap { resamples: ra, seed: sa, threshold: ta, workers: wa },
                JobKind::Bootstrap { resamples: rb, seed: sb, threshold: tb, workers: wb },
            ) => {
                assert_eq!((ra, sa, wa), (rb, sb, wb));
                assert_eq!(ta.to_bits(), tb.to_bits());
            }
            other => panic!("unexpected kinds {other:?}"),
        }
        match (&tcp.panel, &http.panel) {
            (PanelSource::Inline(a), PanelSource::Inline(b)) => assert_eq!(a, b),
            other => panic!("unexpected panels {other:?}"),
        }
        // and the path-derived cmd wins over a conflicting body cmd
        match request_from_parts("fit", &body).unwrap() {
            Request::Job(spec) => assert!(matches!(spec.kind, JobKind::Fit)),
            other => panic!("unexpected request {other:?}"),
        }
    }

    #[test]
    fn frames_are_single_lines_with_escaped_payloads() {
        let e = frame_error(Some("a\"b"), "boom\nline2");
        assert!(!e.contains('\n'), "frames must stay newline-free: {e:?}");
        let j = parse_json(&e).unwrap();
        assert_eq!(j.get("id").and_then(Json::as_str), Some("a\"b"));
        assert_eq!(j.get("message").and_then(Json::as_str), Some("boom\nline2"));
        let p = frame_progress("i", "ordering", 3, 31);
        let pj = parse_json(&p).unwrap();
        assert_eq!(pj.get("step").and_then(Json::as_usize), Some(3));
        assert_eq!(pj.get("total").and_then(Json::as_usize), Some(31));
        let a = parse_json(&frame_accepted("i", 4)).unwrap();
        assert_eq!(a.get("queue_depth").and_then(Json::as_usize), Some(4));
        let c = parse_json(&frame_canceled("i")).unwrap();
        assert_eq!(c.get("event").and_then(Json::as_str), Some("canceled"));
        let k = parse_json(&frame_ack(None, "shutdown", true)).unwrap();
        assert_eq!(k.get("of").and_then(Json::as_str), Some("shutdown"));
        assert_eq!(k.get("ok").and_then(Json::as_bool), Some(true));
    }

    #[test]
    fn traced_result_frame_carries_timing_and_none_is_plain() {
        let data = "{\"kind\":\"fit\"}";
        // None must be byte-identical to the untimed builder, so every
        // existing consumer of frame_result sees unchanged bytes
        assert_eq!(
            frame_result_traced(Some("a"), false, 1.5, data, None),
            frame_result(Some("a"), false, 1.5, data)
        );
        let timing = "{\"trace\":\"00ff\",\"total_ms\":2.5,\"spans\":[]}";
        let f = frame_result_traced(Some("a"), true, 2.5, data, Some(timing));
        let j = parse_json(&f).unwrap();
        assert_eq!(j.get("event").and_then(Json::as_str), Some("result"));
        let t = j.get("timing").expect("timing object");
        assert_eq!(t.get("trace").and_then(Json::as_str), Some("00ff"));
        assert_eq!(t.get("total_ms").and_then(Json::as_f64), Some(2.5));
        assert!(j.get("data").is_some());
    }
}
