//! Descriptive statistics on data panels: means, variances, covariance,
//! standardization, correlation matrices — including the *masked* variants
//! the XLA engine's zero-padded buffers rely on.

use crate::linalg::Mat;

/// Mean of a slice.
pub fn mean(x: &[f64]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    x.iter().sum::<f64>() / x.len() as f64
}

/// Population variance (ddof = 0 — matches numpy's default, which the
/// reference LiNGAM implementation uses).
pub fn var(x: &[f64]) -> f64 {
    let m = mean(x);
    x.iter().map(|&v| (v - m) * (v - m)).sum::<f64>() / x.len() as f64
}

/// Population standard deviation.
pub fn std(x: &[f64]) -> f64 {
    var(x).sqrt()
}

/// Population covariance of two equal-length slices.
pub fn cov(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let (mx, my) = (mean(x), mean(y));
    x.iter().zip(y).map(|(&a, &b)| (a - mx) * (b - my)).sum::<f64>() / x.len() as f64
}

/// Standardize in place to zero mean, unit (population) std.
pub fn standardize(x: &mut [f64]) {
    let m = mean(x);
    let s = std(x).max(1e-12);
    for v in x.iter_mut() {
        *v = (*v - m) / s;
    }
}

/// Standardize every column of a data panel `[n, d]`.
pub fn standardize_cols(x: &Mat) -> Mat {
    let (n, d) = (x.rows(), x.cols());
    let mut out = x.clone();
    for c in 0..d {
        let mut col = x.col(c);
        standardize(&mut col);
        for r in 0..n {
            out[(r, c)] = col[r];
        }
    }
    out
}

/// Correlation matrix of the columns of `x` ([n, d] → [d, d]).
pub fn correlation(x: &Mat) -> Mat {
    let xs = standardize_cols(x);
    xs.t().matmul(&xs).scale(1.0 / x.rows() as f64)
}

/// Quantile (linear interpolation, q in [0,1]) of a slice.
///
/// NaN-tolerant: `total_cmp` sorts NaNs to the top end instead of the
/// `partial_cmp().unwrap()` panic (this sits under [`median_sq_dist`], on
/// the SVGD baseline path, where a degenerate particle set can inject
/// NaN distances).
pub fn quantile(x: &[f64], q: f64) -> f64 {
    assert!(!x.is_empty());
    let mut v = x.to_vec();
    v.sort_by(f64::total_cmp);
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Median absolute pairwise distance — SVGD's bandwidth ("median
/// heuristic") helper. `x` is a set of points given as rows.
///
/// Non-finite distances (degenerate particles with NaN/inf coordinates)
/// are excluded before taking the median: leaving them in would either
/// bias the quantile (NaN sorts above every number under `total_cmp`) or
/// return a NaN that callers like SVGD's `.max(1e-12)` bandwidth floor
/// would silently swallow.
pub fn median_sq_dist(points: &Mat) -> f64 {
    let n = points.rows();
    let mut d2 = Vec::with_capacity(n * (n - 1) / 2);
    for i in 0..n {
        for j in (i + 1)..n {
            let dist: f64 = points
                .row(i)
                .iter()
                .zip(points.row(j))
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            if dist.is_finite() {
                d2.push(dist);
            }
        }
    }
    if d2.is_empty() {
        1.0
    } else {
        quantile(&d2, 0.5)
    }
}

/// Excess kurtosis (non-Gaussianity check for simulators: LiNGAM needs
/// non-Gaussian noise, and our generators should produce it).
pub fn excess_kurtosis(x: &[f64]) -> f64 {
    let m = mean(x);
    let s2 = var(x).max(1e-300);
    let m4 = x.iter().map(|&v| (v - m).powi(4)).sum::<f64>() / x.len() as f64;
    m4 / (s2 * s2) - 3.0
}

// ---------------------------------------------------------------------
// Masked variants: these define the exact semantics the padded XLA
// buffers use (zero-padded rows with a row mask; divide by n_valid).
// The Pallas kernel and ref.py implement the same formulas.
// ---------------------------------------------------------------------

/// Masked mean: Σ mask·x / Σ mask.
pub fn masked_mean(x: &[f64], mask: &[f64]) -> f64 {
    let n: f64 = mask.iter().sum();
    x.iter().zip(mask).map(|(&v, &m)| v * m).sum::<f64>() / n.max(1.0)
}

/// Masked population std.
pub fn masked_std(x: &[f64], mask: &[f64]) -> f64 {
    let n: f64 = mask.iter().sum::<f64>().max(1.0);
    let m = masked_mean(x, mask);
    let s2 = x
        .iter()
        .zip(mask)
        .map(|(&v, &w)| w * (v - m) * (v - m))
        .sum::<f64>()
        / n;
    s2.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let x = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&x), 2.5);
        assert!((var(&x) - 1.25).abs() < 1e-12);
    }

    #[test]
    fn standardize_unit() {
        let mut x = vec![10.0, 20.0, 30.0, 40.0, 55.0];
        standardize(&mut x);
        assert!(mean(&x).abs() < 1e-12);
        assert!((std(&x) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn correlation_diag_ones() {
        let x = Mat::from_fn(100, 3, |r, c| ((r * (c + 3) * 31 + c) % 23) as f64);
        let r = correlation(&x);
        for i in 0..3 {
            assert!((r[(i, i)] - 1.0).abs() < 1e-10);
        }
        // symmetry
        assert!((r[(0, 1)] - r[(1, 0)]).abs() < 1e-12);
        assert!(r.as_slice().iter().all(|v| v.abs() <= 1.0 + 1e-9));
    }

    #[test]
    fn cov_of_identical_is_var() {
        let x = [3.0, 1.0, 4.0, 1.0, 5.0];
        assert!((cov(&x, &x) - var(&x)).abs() < 1e-12);
    }

    #[test]
    fn quantile_endpoints() {
        let x = [5.0, 1.0, 3.0];
        assert_eq!(quantile(&x, 0.0), 1.0);
        assert_eq!(quantile(&x, 1.0), 5.0);
        assert_eq!(quantile(&x, 0.5), 3.0);
    }

    #[test]
    fn quantile_tolerates_nan() {
        // regression: partial_cmp().unwrap() used to panic here
        let x = [1.0, f64::NAN, 3.0];
        assert_eq!(quantile(&x, 0.0), 1.0);
        assert_eq!(quantile(&x, 0.5), 3.0); // NaN sorts above every number
        assert!(quantile(&x, 1.0).is_nan());
        let all_nan = [f64::NAN, f64::NAN];
        assert!(quantile(&all_nan, 0.5).is_nan());
    }

    #[test]
    fn median_sq_dist_excludes_degenerate_particles() {
        // one NaN particle must not bias (or poison) the bandwidth
        let mut pts = Mat::from_fn(4, 2, |r, c| (r * 2 + c) as f64);
        let clean = median_sq_dist(&pts);
        pts[(3, 0)] = f64::NAN;
        let with_nan = median_sq_dist(&pts);
        assert!(with_nan.is_finite());
        // remaining finite pairs are a subset of the clean ones
        assert!(with_nan <= clean);
        // all particles degenerate → fallback bandwidth, not NaN
        let all_bad = Mat::from_fn(3, 2, |_, _| f64::NAN);
        assert_eq!(median_sq_dist(&all_bad), 1.0);
    }

    #[test]
    fn masked_matches_unmasked_when_full() {
        let x = [2.0, 4.0, 6.0];
        let mask = [1.0, 1.0, 1.0];
        assert!((masked_mean(&x, &mask) - mean(&x)).abs() < 1e-12);
        assert!((masked_std(&x, &mask) - std(&x)).abs() < 1e-12);
    }

    #[test]
    fn masked_ignores_padding() {
        // padded with zeros + zero mask — the XLA buffer layout
        let x = [2.0, 4.0, 6.0, 0.0, 0.0];
        let mask = [1.0, 1.0, 1.0, 0.0, 0.0];
        assert!((masked_mean(&x, &mask) - 4.0).abs() < 1e-12);
        assert!((masked_std(&x, &mask) - std(&[2.0, 4.0, 6.0])).abs() < 1e-12);
    }

    #[test]
    fn kurtosis_signs() {
        let mut rng = crate::util::rng::Pcg64::seed_from_u64(1);
        let gauss: Vec<f64> = (0..40_000).map(|_| rng.normal()).collect();
        let unif: Vec<f64> = (0..40_000).map(|_| rng.f64()).collect();
        let lap: Vec<f64> = (0..40_000).map(|_| rng.laplace(1.0)).collect();
        assert!(excess_kurtosis(&gauss).abs() < 0.15);
        assert!(excess_kurtosis(&unif) < -1.0); // uniform: −1.2
        assert!(excess_kurtosis(&lap) > 1.5); // laplace: +3
    }
}
