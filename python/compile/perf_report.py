"""L1/L2 performance model report (DESIGN.md / EXPERIMENTS.md #Perf).

``interpret=True`` Pallas timings are CPU emulation — NOT a TPU proxy —
so real-TPU performance is *estimated* structurally:

- VMEM footprint of one (i, j-tile) program of the residual-entropy
  kernel, vs the ~16 MiB VMEM budget of a TPUv4 core;
- FLOP balance between the MXU-bound correlation matmul and the
  VPU-bound entropy sweep;
- arithmetic intensity of the kernel (flops per HBM byte), which decides
  whether the kernel is compute- or bandwidth-bound at TPU ratios;
- per-iteration host<->device transfer volume of the fused order_step
  artifact.

Also dumps XLA HLO op statistics per artifact as a cheap fusion audit:
the interpret-mode pallas kernel should lower to a single while-loop
with fused elementwise bodies, not a soup of standalone kernels.

Usage: python -m compile.perf_report [--buckets "4096x32,16384x64"]
"""

import argparse

import jax

from compile import model
from compile.kernels import causal_order

VMEM_BUDGET = 16 * 1024 * 1024  # TPUv4 ~16 MiB/core
# TPUv4 reference ratios (per chip): 275 TF/s bf16 MXU, ~75 TF/s f32 VPU
# (vector), 1200 GB/s HBM.
MXU_FLOPS = 275e12
VPU_FLOPS = 75e12 / 4  # f32 transcendental-heavy estimate
HBM_BPS = 1200e9


def report_bucket(n, d, block_j):
    bj = min(d, block_j)
    # shrink the j-tile until one program fits the VMEM budget — the
    # schedule knob BlockSpec exposes (results are tile-invariant, see
    # python/tests/test_kernel.py::test_hr_kernel_blocking_invariant)
    while bj > 1 and causal_order.vmem_bytes(n, d, bj) > VMEM_BUDGET:
        bj //= 2
    vmem = causal_order.vmem_bytes(n, d, bj)
    # entropy sweep: ~14 flops per (t, i, j) element (residual + both
    # nonlinearities + reductions)
    sweep_flops = causal_order.flops(n, d)
    # correlation matmul: 2 n d^2 (the MXU hoist)
    mxu_flops = 2 * n * d * d
    # HBM traffic per full HR computation: panel read once per i (no
    # reuse across i without a second-level cache) + outputs
    hbm_bytes = 4 * (d * (n + n * d) + d * d)
    intensity = sweep_flops / hbm_bytes

    t_vpu = sweep_flops / VPU_FLOPS
    t_hbm = hbm_bytes / HBM_BPS
    bound = "compute (VPU)" if t_vpu > t_hbm else "bandwidth (HBM)"
    t_est = max(t_vpu, t_hbm)

    print(f"\n  bucket {n}x{d} (j-tile {bj})")
    print(f"    VMEM/program      : {vmem / 1024:.0f} KiB  ({100 * vmem / VMEM_BUDGET:.1f}% of budget)")
    print(f"    entropy sweep     : {sweep_flops / 1e9:.2f} GFLOP (VPU)")
    print(f"    correlation matmul: {mxu_flops / 1e9:.3f} GFLOP (MXU) — {100 * mxu_flops / sweep_flops:.1f}% of sweep")
    print(f"    HBM traffic       : {hbm_bytes / 1e6:.1f} MB, intensity {intensity:.1f} flop/B → {bound}")
    print(f"    est. TPUv4 time   : {t_est * 1e3:.3f} ms per HR matrix "
          f"({d - 1} calls/fit → {t_est * (d - 1) * 1e3:.1f} ms ordering est.)")
    # transfer per fused order_step call (pad + masks up, panel + k down)
    up = 4 * (n * d + n + d)
    down = 4 * (n * d + 1 + d)
    print(f"    PJRT transfer/call: {up / 1e6:.2f} MB up, {down / 1e6:.2f} MB down")


def hlo_op_stats(n, d):
    """Fusion audit: op histogram of the lowered order_step HLO."""
    import collections

    import jax.numpy as jnp

    x = jax.ShapeDtypeStruct((n, d), jnp.float32)
    rm = jax.ShapeDtypeStruct((n,), jnp.float32)
    cm = jax.ShapeDtypeStruct((d,), jnp.float32)
    lowered = jax.jit(model.order_step).lower(x, rm, cm)
    shlo = str(lowered.compiler_ir("stablehlo"))
    ops = collections.Counter()
    for tok in shlo.replace("(", " ").split():
        if tok.startswith("stablehlo."):
            ops[tok.split("stablehlo.")[1].strip('"')] += 1
    top = ", ".join(f"{k}:{v}" for k, v in ops.most_common(12))
    print(f"\n  order_step {n}x{d} stablehlo op histogram (top12): {top}")
    print(f"    while loops: {ops.get('while', 0)} (pallas grid) — "
          f"dot_general: {ops.get('dot_general', 0)} (MXU candidates)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--buckets", default="1024x16,4096x32,16384x64,65536x128")
    ap.add_argument("--block-j", type=int, default=causal_order.DEFAULT_BLOCK_J)
    args = ap.parse_args()

    print("== L1 kernel performance model (structural; interpret-mode wallclock is NOT a TPU proxy) ==")
    for spec in args.buckets.split(","):
        n, d = spec.strip().split("x")
        report_bucket(int(n), int(d), args.block_j)

    print("\n== L2 fusion audit ==")
    hlo_op_stats(1024, 16)


if __name__ == "__main__":
    main()
