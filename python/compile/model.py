"""L2: the JAX compute graph around the Pallas kernels.

Six exported computations, each AOT-lowered to HLO text by ``aot.py``
for a set of shape buckets and executed from the Rust coordinator via
PJRT (python never runs on the request path):

- ``order_scores(x, row_mask, col_mask) -> k_list``
    Algorithm 1 over a zero-padded panel. Standardization, the
    correlation matmul (the MXU-friendly hoist) and the entropy
    composition live here; the O(D^2 N) residual-entropy sweep is the
    Pallas kernel.

- ``order_step(x, row_mask, col_mask) -> (x', m, k_list)``
    The fused *stateless* hot-path step: scores -> argmax ->
    residualize. One artifact call per DirectLiNGAM iteration instead
    of two, halving host<->device round trips (see EXPERIMENTS.md
    #Perf). Kept as the legacy per-step path and the fusion-ablation
    baseline.

- ``session_init(x, row_mask, col_mask) -> state``
  ``session_scores(state) -> k_list``
  ``session_update(state, m_onehot) -> state``
    The *device-resident* session (kernels/session.py): the panel is
    uploaded and standardized once, then every step runs against the
    packed resident state (standardized cache + correlation matrix,
    residualized in place via the rho^2-clamped closed form with an
    analytic O(D^2) correlation update). Per step only the [D] score
    row comes down and the [D] one-hot choice goes up; the argmax runs
    on the host between the two calls, matching the CPU engines'
    NaN-skip / lowest-index semantics. Artifact names:
    ``session_{init,scores,update}_n{N}_d{D}.hlo.txt`` — lowered with a
    **non-tuple root** (single packed array out) so the Rust runtime
    can hold the output as one resident PJRT buffer.

- ``session_init_batch(x, row_mask, col_mask) -> state``
  ``session_scores_batch(state) -> k_lists``
  ``session_update_batch(state, m_onehots) -> state``
    ``jax.vmap`` of the session kinds over a leading batch axis: B
    same-shape panels uploaded in one ``session_init_batch`` call and
    stepped in lock step — one [B, D] score fetch and one [B, D]
    one-hot upload per step for the whole group, with per-panel argmax
    still on the host. Each batch slice is bitwise the solo artifact's
    output (pinned by python/tests/test_session.py), which is what lets
    the serve layer's fusion window route same-shape jobs through one
    ``XlaBatchSession`` without changing any result. Artifact names:
    ``session_{init,scores,update}_batch_n{N}_d{D}_b{B}.hlo.txt``
    (manifest lines grow a 5th field for B).

- ``var_fit(series, row_mask) -> (m1, resid)``
    Masked VAR(1) least squares for VarLiNGAM (normal equations; the
    SPD inverse is a Newton-Schulz iteration so the artifact stays free
    of LAPACK custom-calls).
"""

import jax
import jax.numpy as jnp

from compile.kernels import causal_order, residualize, ref
from compile.kernels.session import (  # noqa: F401  (AOT entry points)
    session_init,
    session_init_batch,
    session_scores,
    session_scores_batch,
    session_update,
    session_update_batch,
)


def order_scores(x, row_mask, col_mask):
    """k_list over active variables; inactive entries = ref.INACTIVE."""
    xs, n_valid = ref.masked_standardize(x, row_mask, col_mask)
    rho = xs.T @ xs / n_valid
    h = ref.column_entropies(xs, n_valid)
    hr = causal_order.residual_entropy_matrix(xs, rho, n_valid)
    diff = (h[None, :] + hr) - (h[:, None] + hr.T)
    pen = jnp.minimum(0.0, diff) ** 2
    k = -jnp.sum(pen * col_mask[None, :], axis=1)
    return jnp.where(col_mask > 0, k, ref.INACTIVE)


def order_step(x, row_mask, col_mask):
    """Fused DirectLiNGAM iteration. Returns (x_next, m, k_list).

    The on-device argmax is NaN-safe (ref.safe_argmax): without the guard
    a degenerate panel whose scores go NaN would elect a NaN-scored
    variable inside the artifact, where the Rust host-side checks cannot
    see it until the invalid index comes back."""
    k_list = order_scores(x, row_mask, col_mask)
    m = ref.safe_argmax(k_list)
    m_onehot = jnp.zeros_like(col_mask).at[m].set(1.0)

    rm = row_mask[:, None]
    n_valid = jnp.maximum(jnp.sum(row_mask), 1.0)
    mean = jnp.sum(x * rm, axis=0) / n_valid
    centered = (x - mean[None, :]) * rm
    xm = centered @ m_onehot
    var_m = jnp.maximum(jnp.sum(xm * xm) / n_valid, 1e-30)
    beta = (centered.T @ xm) / n_valid / var_m
    keep = col_mask * (1.0 - m_onehot)
    x_next = residualize.residualize_panel(centered, xm, beta, keep)
    return x_next, m.astype(jnp.int32), k_list


def var_fit(series, row_mask):
    """Masked VAR(1) least squares.

    series: [T, D] zero-padded; row_mask: [T] with the first t_valid
    entries 1. Returns (M1 [D, D], residuals [T-1, D] zero-padded).
    """
    past = series[:-1, :]
    future = series[1:, :]
    # a (past, future) pair is valid iff both rows are valid
    pm = (row_mask[:-1] * row_mask[1:])[:, None]
    n_valid = jnp.maximum(jnp.sum(pm), 1.0)
    p_mean = jnp.sum(past * pm, axis=0) / n_valid
    f_mean = jnp.sum(future * pm, axis=0) / n_valid
    pc = (past - p_mean[None, :]) * pm
    fc = (future - f_mean[None, :]) * pm
    d = series.shape[1]
    # relative ridge keeps the gram well-conditioned at any data scale
    gram = pc.T @ pc
    ridge = 1e-6 * (jnp.trace(gram) / d + 1.0)
    gram = gram + ridge * jnp.eye(d, dtype=series.dtype)
    m1t = _spd_inverse(gram) @ (pc.T @ fc)  # [D, D], M1 transposed
    resid = (fc - pc @ m1t) * pm
    return m1t.T, resid


def _spd_inverse(a, iters=40):
    """SPD matrix inverse via Newton-Schulz iteration (pure matmuls).

    jnp.linalg.solve/cholesky lower to LAPACK typed-FFI custom-calls that
    the pinned xla_extension (0.5.1) cannot execute; Newton-Schulz
    X <- X (2I - A X) stays in plain HLO, is MXU-friendly on real TPUs,
    and converges quadratically from X0 = I / gershgorin_bound(A).
    """
    d = a.shape[0]
    eye2 = 2.0 * jnp.eye(d, dtype=a.dtype)
    # Gershgorin upper bound on the spectral radius (A is SPD)
    bound = jnp.max(jnp.sum(jnp.abs(a), axis=1))
    x = jnp.eye(d, dtype=a.dtype) / bound

    def body(_, x):
        return x @ (eye2 - a @ x)

    return jax.lax.fori_loop(0, iters, body, x)
