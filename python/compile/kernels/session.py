"""L1/L2 session kernels: the device-resident ordering workspace.

The stateless artifacts (``order_scores`` / ``order_step``) re-upload the
panel and re-derive its statistics on every DirectLiNGAM step. This
module is the accelerated analogue of the Rust ``IncrementalSession``
(rust/src/lingam/session.rs): the panel is standardized **once**
(``session_init``) and the per-step work then runs against a packed
device-resident state — the standardized column cache is residualized in
place with the shared rho^2-clamped closed form and the correlation
matrix is updated analytically in O(D^2), so only the score row and the
chosen variable ever round-trip to the host.

Packed state layout (a single f32 array, so the artifact outputs have a
**non-tuple root** and the Rust runtime can keep them resident as one
PJRT buffer — tuple outputs can only come back to the host):

    state: [N + D + 2, D]
      rows 0..N      standardized column cache xs (padded rows and
                     inactive columns exactly 0)
      rows N..N+D    correlation matrix rho (inactive rows/cols 0)
      row  N+D       col_mask (still-active variables)
      row  N+D+1     aux: element 0 = n_valid, rest 0

Three computations, each AOT-lowered per shape bucket by ``aot.py``:

- ``session_init(x, row_mask, col_mask) -> state``
    The one panel upload of a fit: masked standardize + correlation
    matmul, packed into the resident state.
- ``session_scores(state) -> k_list``
    Algorithm 1 over the cached workspace: entropies + the Pallas
    residual-entropy sweep against the **cached** correlations (no
    re-standardize, no correlation matmul). The [D] score row is the
    only per-step download.
- ``session_update(state, m_onehot) -> state``
    Commit the host's choice: residualize the cache in place via
    ``(c_j - rho_jm c_m) / sqrt(1 - rho_jm^2)`` (rho^2-clamped, the
    Pallas update kernel) and update rho analytically,
    ``rho'_jk = (rho_jk - rho_jm rho_km) / (denom_j denom_k)``. The [D]
    one-hot is the only per-step upload.

The argmax between ``session_scores`` and ``session_update`` happens on
the *host* (Rust ``argmax_active``): it is O(D) on data that must be
downloaded anyway, and it keeps the NaN-skip + lowest-index tie-break
semantics bit-identical to the CPU engines.

Why the closed forms are exact: the cached columns are standardized, so
the residual ``c_j - rho_jm c_m`` has mean 0 and variance
``1 - rho_jm^2``; dividing by the rho^2-clamped root re-standardizes it
without touching sample data, and the correlation of two such residuals
expands to the analytic update above. ``python/tests/test_session.py``
pins the per-step agreement against the from-scratch ``order_step_ref``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import causal_order, ref

# Rows appended after the cache and correlation blocks: col_mask + aux.
META_ROWS = 2


def state_shape(n, d):
    """Packed state shape for an (n, d) bucket."""
    return (n + d + META_ROWS, d)


def pack_state(xs, rho, col_mask, n_valid):
    """Assemble the packed state from its components."""
    d = xs.shape[1]
    aux = jnp.zeros((d,), xs.dtype).at[0].set(n_valid)
    return jnp.concatenate(
        [xs, rho.astype(xs.dtype), col_mask[None, :], aux[None, :]], axis=0
    )


def unpack_state(state):
    """Split the packed state; shapes are static at lowering time."""
    d = state.shape[1]
    n = state.shape[0] - d - META_ROWS
    xs = state[:n]
    rho = state[n : n + d]
    col_mask = state[n + d]
    n_valid = state[n + d + 1, 0]
    return xs, rho, col_mask, n_valid


def session_init(x, row_mask, col_mask):
    """Seed the workspace: standardize once, correlate once, pack.

    x: [N, D] zero-padded panel; row_mask: [N]; col_mask: [D].
    Returns state [N + D + 2, D].
    """
    xs, n_valid = ref.masked_standardize(x, row_mask, col_mask)
    rho = (xs.T @ xs / n_valid) * (col_mask[:, None] * col_mask[None, :])
    return pack_state(xs, rho, col_mask, n_valid)


def session_scores(state):
    """k_list over the cached workspace; inactive entries = ref.INACTIVE.

    Identical composition to ``model.order_scores`` except that xs and
    rho come from the resident state instead of being re-derived — the
    entropy pass and the Pallas residual-entropy sweep are the only work
    that still touches sample data (mirroring IncrementalSession).
    """
    xs, rho, col_mask, n_valid = unpack_state(state)
    h = ref.column_entropies(xs, n_valid)
    hr = causal_order.residual_entropy_matrix(xs, rho, n_valid)
    diff = (h[None, :] + hr) - (h[:, None] + hr.T)
    pen = jnp.minimum(0.0, diff) ** 2
    k = -jnp.sum(pen * col_mask[None, :], axis=1)
    return jnp.where(col_mask > 0, k, ref.INACTIVE)


def _update_kernel(xs_ref, cm_ref, rho_m_ref, dinv_ref, keep_ref, out_ref):
    """One j-tile of the closed-form cache residualization.

    xs_ref:    [N, BJ] — standardized cache tile
    cm_ref:    [N, 1]  — cached column of the chosen variable
    rho_m_ref: [1, BJ] — rho[:, m] for the tile
    dinv_ref:  [1, BJ] — 1 / residual_denom(rho[:, m])
    keep_ref:  [1, BJ] — col_mask * (1 - onehot_m)
    out_ref:   [N, BJ] — re-standardized residual tile
    """
    xs = xs_ref[...]
    cm = cm_ref[...]
    rho_m = rho_m_ref[...]
    dinv = dinv_ref[...]
    keep = keep_ref[...]
    # padded rows stay exactly 0: xs and cm are both 0 there
    out_ref[...] = (xs - cm * rho_m) * dinv * keep


@functools.partial(jax.jit, static_argnames=("block_j",))
def residualize_cache(xs, cm, rho_m, dinv, keep, *, block_j=None):
    """Pallas sweep of the standardized-cache closed form. Shapes:
    [N, D], [N], [D], [D], [D] -> [N, D]."""
    n, d = xs.shape
    bj = min(d, block_j or causal_order.DEFAULT_BLOCK_J)
    assert d % bj == 0, f"D={d} must be a multiple of the j-tile {bj}"
    return pl.pallas_call(
        _update_kernel,
        grid=(d // bj,),
        in_specs=[
            pl.BlockSpec((n, bj), lambda j: (0, j)),
            pl.BlockSpec((n, 1), lambda j: (0, 0)),
            pl.BlockSpec((1, bj), lambda j: (0, j)),
            pl.BlockSpec((1, bj), lambda j: (0, j)),
            pl.BlockSpec((1, bj), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((n, bj), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), xs.dtype),
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(xs, cm.reshape(n, 1), rho_m.reshape(1, d), dinv.reshape(1, d), keep.reshape(1, d))


def session_update(state, m_onehot):
    """Commit a choice: residualize the cache, update rho, deactivate m.

    state: [N + D + 2, D]; m_onehot: [D] with a single 1 at the chosen
    (still-active) variable. Returns the next state.
    """
    xs, rho, col_mask, n_valid = unpack_state(state)
    d = state.shape[1]
    keep = col_mask * (1.0 - m_onehot)
    rho_m = rho @ m_onehot  # column m of the cached correlations
    # shared rho^2-clamped denominator (same guard as the HR kernel)
    dinv = 1.0 / ref.residual_denom(rho_m)
    cm = xs @ m_onehot

    # 1) cache update: one fused elementwise pass (Pallas j-tiles)
    xs2 = residualize_cache(xs, cm, rho_m, dinv, keep)

    # 2) closed-form correlation update over the remaining active block;
    # the clamp keeps later denominators well-defined when a pair
    # collapses to collinearity. Removed/inactive rows and columns are
    # zeroed (the CPU session leaves them stale; zeroing is equivalent —
    # they are never consumed — and keeps the state deterministic).
    rho2 = (rho - rho_m[:, None] * rho_m[None, :]) * dinv[:, None] * dinv[None, :]
    rho2 = jnp.clip(rho2, -1.0, 1.0) * (keep[:, None] * keep[None, :])
    # pin the active diagonal back to exactly 1 (float noise from the
    # clamped denominators would otherwise drift it)
    eye = jnp.eye(d, dtype=state.dtype)
    rho2 = rho2 * (1.0 - eye) + eye * keep[None, :]

    return pack_state(xs2, rho2, keep, n_valid)


def session_init_batch(x, row_mask, col_mask):
    """Batched ``session_init``: B same-shape panels in one upload.

    x: [B, N, D]; row_mask: [B, N]; col_mask: [B, D]. Returns
    state [B, N + D + 2, D]. ``jax.vmap`` lowers the per-panel
    computation unchanged (the Pallas sweeps gain a leading batch
    axis), so each slice is bitwise the solo artifact's output —
    ``python/tests/test_session.py`` pins that parity. The serve
    layer's fusion window drives these through ``XlaBatchSession``:
    one upload and one score fetch per lock step for the whole group.
    """
    return jax.vmap(session_init)(x, row_mask, col_mask)


def session_scores_batch(state):
    """Batched ``session_scores``: [B, N + D + 2, D] -> [B, D]."""
    return jax.vmap(session_scores)(state)


def session_update_batch(state, m_onehot):
    """Batched ``session_update``; a per-panel all-zero one-hot is a
    safe no-op (keep == col_mask, cache and rho untouched), which is how
    finished or dropped lanes ride along in a live batch."""
    return jax.vmap(session_update)(state, m_onehot)


def session_step_host(state):
    """Host-mirror of one full device-session step (tests + the Rust
    host-mirror fallback's reference): scores -> NaN-safe argmax ->
    update. Returns (state', m, k_list)."""
    k_list = session_scores(state)
    m = ref.safe_argmax(k_list)
    m_onehot = jnp.zeros((state.shape[1],), state.dtype).at[m].set(1.0)
    return session_update(state, m_onehot), m.astype(jnp.int32), k_list
