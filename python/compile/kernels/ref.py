"""Pure-jnp oracle for the AcceleratedLiNGAM kernels.

Defines the exact *masked* semantics the AOT artifacts implement: data
panels arrive zero-padded to a shape bucket ``[N, D]`` with a row mask
(valid samples) and a column mask (still-active variables); statistics
divide by ``n_valid`` rather than N.

The formulas mirror the Rust `VectorizedEngine` (rust/src/lingam/engine.rs)
so all three implementations can be cross-checked:

  H(u)      = H_nu - k1*(E[log cosh u] - gamma)^2 - k2*(E[u e^{-u^2/2}])^2
  r_ij      = (xs_i - rho_ij xs_j) / sqrt(1 - rho_ij^2)
  diff_ij   = (H[j] + H(r_ij)) - (H[i] + H(r_ji))
  k_list[i] = -sum_j active_j . min(0, diff_ij)^2
"""

import jax.numpy as jnp

H_NU = 1.4189385332046727  # (1 + log 2pi) / 2
K1 = 79.047
K2 = 7.4129
GAMMA = 0.37457

# 1 - rho^2 is clipped here before the rsqrt: keeps the self-pair (rho=1)
# finite; its diff is identically zero so the clip value is immaterial.
DENOM_EPS = 1e-12
STD_EPS = 1e-7

# Score assigned to masked-out variables (argmax must never pick them).
INACTIVE = -1e30


def residual_denom(rho):
    """sqrt(1 - rho^2) with rho^2 clamped to <= 1 *before* the subtraction,
    mirroring the Rust pair kernel's hardening: duplicated or collinear
    columns push the float rho^2 past 1, and while the DENOM_EPS floor
    already keeps the sqrt real, clamping first pins the same closed form
    on both sides of the engine-agreement tests (and keeps the guard
    robust if the floor is ever tuned)."""
    rho2 = jnp.minimum(rho * rho, 1.0)
    return jnp.sqrt(jnp.maximum(1.0 - rho2, DENOM_EPS))


def safe_argmax(k_list):
    """NaN-safe argmax over a k_list.

    jnp.argmax propagates NaN (a single NaN score wins the max), so a
    degenerate panel could elect a NaN-scored variable on device. Rewrite
    NaN to the INACTIVE sentinel first — the same policy as the Rust
    `argmax_active`, which skips NaN scores entirely."""
    return jnp.argmax(jnp.where(jnp.isnan(k_list), INACTIVE, k_list))


def log_cosh(u):
    """Numerically-stable log cosh."""
    a = jnp.abs(u)
    return a + jnp.log1p(jnp.exp(-2.0 * a)) - jnp.log(2.0)


def gauss_score(u):
    """u * exp(-u^2/2)."""
    return u * jnp.exp(-0.5 * u * u)


def masked_standardize(x, row_mask, col_mask):
    """Standardize columns under the row mask; padded rows end up exactly 0.

    x: [N, D] zero-padded; row_mask: [N]; col_mask: [D].
    Returns (xs, n_valid).
    """
    rm = row_mask[:, None]
    n_valid = jnp.maximum(jnp.sum(row_mask), 1.0)
    mean = jnp.sum(x * rm, axis=0) / n_valid
    centered = (x - mean[None, :]) * rm
    var = jnp.sum(centered * centered, axis=0) / n_valid
    std = jnp.sqrt(jnp.maximum(var, 0.0))
    xs = centered / jnp.maximum(std, STD_EPS)[None, :]
    return xs * col_mask[None, :], n_valid


def column_entropies(xs, n_valid):
    """Max-ent entropy of each (already standardized, masked) column.

    log_cosh(0) = gauss_score(0) = 0, so zero-padded rows contribute
    nothing to the sums — no extra mask multiply is needed.
    """
    e_lc = jnp.sum(log_cosh(xs), axis=0) / n_valid
    e_gs = jnp.sum(gauss_score(xs), axis=0) / n_valid
    return H_NU - K1 * (e_lc - GAMMA) ** 2 - K2 * e_gs**2


def residual_entropy_matrix_ref(xs, rho, n_valid):
    """HR[i, j] = H(standardized residual of regressing x_i on x_j).

    The O(D^2 N) hot spot — this is what the Pallas kernel computes.
    Reference implementation materializes the full [N, D, D] residual
    tensor (memory-hungry; fine for test sizes).
    """
    denom = residual_denom(rho)  # [D, D]
    # R[t, i, j] = (xs[t,i] - rho[i,j] xs[t,j]) / denom[i,j]
    r = (xs[:, :, None] - rho[None, :, :] * xs[:, None, :]) / denom[None, :, :]
    e_lc = jnp.sum(log_cosh(r), axis=0) / n_valid
    e_gs = jnp.sum(gauss_score(r), axis=0) / n_valid
    return H_NU - K1 * (e_lc - GAMMA) ** 2 - K2 * e_gs**2


def order_scores_ref(x, row_mask, col_mask):
    """k_list over active variables (Algorithm 1, vectorized form)."""
    xs, n_valid = masked_standardize(x, row_mask, col_mask)
    rho = xs.T @ xs / n_valid
    h = column_entropies(xs, n_valid)
    hr = residual_entropy_matrix_ref(xs, rho, n_valid)
    diff = (h[None, :] + hr) - (h[:, None] + hr.T)
    pen = jnp.minimum(0.0, diff) ** 2
    k = -jnp.sum(pen * col_mask[None, :], axis=1)
    return jnp.where(col_mask > 0, k, INACTIVE)


def residualize_ref(x, row_mask, col_mask, m_onehot):
    """Least-squares removal of variable m from every other column.

    x_j' = (x_j - mean_j) - beta_j (x_m - mean_m),  beta_j = cov(j,m)/var_m.
    Column m itself is zeroed (it is deactivated after the step), and
    padded rows are re-zeroed to preserve the buffer invariant.
    """
    rm = row_mask[:, None]
    n_valid = jnp.maximum(jnp.sum(row_mask), 1.0)
    mean = jnp.sum(x * rm, axis=0) / n_valid
    centered = (x - mean[None, :]) * rm
    xm = centered @ m_onehot  # [N]
    var_m = jnp.maximum(jnp.sum(xm * xm) / n_valid, 1e-30)
    beta = (centered.T @ xm) / n_valid / var_m  # [D]
    out = centered - xm[:, None] * beta[None, :]
    keep = col_mask * (1.0 - m_onehot)
    return out * keep[None, :] * rm


def order_step_ref(x, row_mask, col_mask):
    """Fused step: scores -> argmax -> residualize. Returns (x', m, k_list)."""
    k_list = order_scores_ref(x, row_mask, col_mask)
    m = safe_argmax(k_list)
    m_onehot = jnp.zeros_like(col_mask).at[m].set(1.0)
    x_next = residualize_ref(x, row_mask, col_mask, m_onehot)
    return x_next, m, k_list
