"""L1 Pallas kernel: least-squares residualization update.

After the exogenous variable m is chosen, every remaining active column
is replaced by its regression residual on x_m:

    x_j' = (x_j - mean_j) - beta_j (x_m - mean_m)

The O(N D) elementwise update runs as a j-tiled Pallas kernel; the scalar
regression coefficients beta (one matvec) are computed in L2 and streamed
in. Padded rows and the deactivated column are re-zeroed inside the
kernel, preserving the buffer invariant the masked statistics rely on.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_J = 128


def _kernel(xc_ref, xm_ref, beta_ref, keep_ref, out_ref):
    """One j-tile program.

    xc_ref:   [N, BJ] — centered panel tile (padded rows already 0)
    xm_ref:   [N, 1]  — centered chosen column
    beta_ref: [1, BJ] — regression coefficients cov(j,m)/var(m)
    keep_ref: [1, BJ] — col_mask * (1 - onehot_m)
    out_ref:  [N, BJ]
    """
    xc = xc_ref[...]
    xm = xm_ref[...]
    beta = beta_ref[...]
    keep = keep_ref[...]
    out_ref[...] = (xc - xm * beta) * keep


@functools.partial(jax.jit, static_argnames=("block_j",))
def residualize_panel(centered, xm, beta, keep, *, block_j=None):
    """Apply the update on a centered panel. Shapes: [N,D], [N], [D], [D]."""
    n, d = centered.shape
    bj = min(d, block_j or DEFAULT_BLOCK_J)
    assert d % bj == 0, f"D={d} must be a multiple of the j-tile {bj}"
    return pl.pallas_call(
        _kernel,
        grid=(d // bj,),
        in_specs=[
            pl.BlockSpec((n, bj), lambda j: (0, j)),
            pl.BlockSpec((n, 1), lambda j: (0, 0)),
            pl.BlockSpec((1, bj), lambda j: (0, j)),
            pl.BlockSpec((1, bj), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((n, bj), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), centered.dtype),
        interpret=True,
    )(centered, xm.reshape(n, 1), beta.reshape(1, d), keep.reshape(1, d))
