"""L1 Pallas kernel: the residual-entropy matrix — DirectLiNGAM's O(D^2 N)
hot spot (Algorithm 1's inner pair loop).

Parallelization scheme (the TPU re-think of the paper's CUDA design, see
DESIGN.md #Hardware-Adaptation):

  * CUDA: one thread-block per candidate root i, threads over j, shared-
    memory tree reductions.
  * Here: 2-D Pallas grid over (i, j-tile). Each program owns one
    (candidate i, tile of j) pair, streams x_i plus a [N, BJ] panel tile
    through VMEM, and reduces the log-cosh / gauss-score expectations with
    vectorized sums over the sample axis (VPU lanes play the role of the
    warp; no atomics are needed because every program owns its own output
    tile, mirroring the paper's observation that k_list updates need no
    ordering).

The kernel is lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so the interpret path (plain HLO) is the
correctness + artifact route; real-TPU performance is *estimated* in
DESIGN.md from the VMEM/MXU model, never measured here.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK_J = 128


def _kernel(xi_ref, xs_ref, rho_ref, nv_ref, out_ref):
    """One (i, j-tile) program.

    xi_ref:  [N, 1]   — candidate root column i (standardized)
    xs_ref:  [N, BJ]  — tile of the standardized panel
    rho_ref: [1, BJ]  — correlations rho[i, j] for the tile
    nv_ref:  [1, 1]   — n_valid
    out_ref: [1, BJ]  — HR[i, j] for the tile
    """
    xi = xi_ref[...]  # [N, 1]
    xs = xs_ref[...]  # [N, BJ]
    rho = rho_ref[...]  # [1, BJ]
    nv = nv_ref[0, 0]
    # rho^2-clamped denominator (degenerate-panel hardening), shared with
    # the jnp oracle so kernel and reference can never desynchronize
    denom = ref.residual_denom(rho)  # [1, BJ]
    r = (xi - rho * xs) / denom  # [N, BJ]; padded rows stay exactly 0
    e_lc = jnp.sum(ref.log_cosh(r), axis=0, keepdims=True) / nv  # [1, BJ]
    e_gs = jnp.sum(ref.gauss_score(r), axis=0, keepdims=True) / nv
    out_ref[...] = ref.H_NU - ref.K1 * (e_lc - ref.GAMMA) ** 2 - ref.K2 * e_gs**2


@functools.partial(jax.jit, static_argnames=("block_j",))
def residual_entropy_matrix(xs, rho, n_valid, *, block_j=None):
    """HR[i, j] = H((xs_i - rho_ij xs_j)/sqrt(1-rho_ij^2)) via Pallas.

    xs: [N, D] standardized masked panel; rho: [D, D]; n_valid: scalar.
    The panel is passed twice: once blocked as the candidate column i
    (BlockSpec picks column i of the array), once as the j-tile.
    """
    n, d = xs.shape
    bj = min(d, block_j or DEFAULT_BLOCK_J)
    assert d % bj == 0, f"D={d} must be a multiple of the j-tile {bj}"
    nv = jnp.asarray(n_valid, xs.dtype).reshape(1, 1)
    return pl.pallas_call(
        _kernel,
        grid=(d, d // bj),
        in_specs=[
            pl.BlockSpec((n, 1), lambda i, j: (0, i)),  # x_i column
            pl.BlockSpec((n, bj), lambda i, j: (0, j)),  # panel j-tile
            pl.BlockSpec((1, bj), lambda i, j: (i, j)),  # rho row-tile
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),  # n_valid
        ],
        out_specs=pl.BlockSpec((1, bj), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, d), xs.dtype),
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(xs, xs, rho.astype(xs.dtype), nv)


def vmem_bytes(n, d, block_j=DEFAULT_BLOCK_J, dtype_bytes=4):
    """VMEM footprint model for one program (DESIGN.md #Perf):
    x_i column + panel tile + residual tile + rho/output rows."""
    bj = min(d, block_j)
    return dtype_bytes * (n + 2 * n * bj + 2 * bj)


def flops(n, d):
    """Approximate flop count of the full HR matrix (for the roofline
    estimate): ~14 flops per (t, i, j) element for residual + both
    nonlinearities + reductions."""
    return 14 * n * d * d
