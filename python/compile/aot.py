"""AOT lowering: jax -> stablehlo -> XLA computation -> **HLO text**.

HLO text (not a serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Emits one artifact per (computation, shape-bucket):

    artifacts/order_scores_n{N}_d{D}.hlo.txt
    artifacts/order_step_n{N}_d{D}.hlo.txt
    artifacts/session_init_n{N}_d{D}.hlo.txt
    artifacts/session_scores_n{N}_d{D}.hlo.txt
    artifacts/session_update_n{N}_d{D}.hlo.txt
    artifacts/var_fit_t{T}_d{D}.hlo.txt

plus the batched session kinds (``jax.vmap`` over a leading batch
axis, for the serve layer's fusion window):

    artifacts/session_init_batch_n{N}_d{D}_b{B}.hlo.txt
    artifacts/session_scores_batch_n{N}_d{D}_b{B}.hlo.txt
    artifacts/session_update_batch_n{N}_d{D}_b{B}.hlo.txt

plus ``artifacts/manifest.txt`` (one line per artifact:
``kind n d path``, with a fifth ``b`` field before the path for the
batched kinds: ``kind n d b path``) that the Rust ArtifactRegistry
reads to pick the smallest bucket covering a request.

The stateless kinds are lowered with ``return_tuple=True`` (the loader
downloads and decomposes the tuple on the host). The ``session_*``
kinds return a **single array** and are lowered with
``return_tuple=False``: a non-tuple root is what lets the Rust runtime
keep the output resident on the device as one PJRT buffer and feed it
straight back into the next step (kernels/session.py #state-layout).

Usage: python -m compile.aot --out-dir ../artifacts [--full]
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import session as session_kernels

# Default shape buckets. Scores/step buckets: (n_samples, dims);
# var_fit buckets: (t_len, dims). --full adds the larger sizes used by
# the paper-scale benches.
ORDER_BUCKETS = [
    (256, 8),
    (1024, 8),
    (1024, 16),
    (4096, 16),
    (4096, 32),
    (4096, 64),
    (16384, 32),
]
ORDER_BUCKETS_FULL = ORDER_BUCKETS + [
    (16384, 64),
    (16384, 128),
    (65536, 128),
]
VAR_BUCKETS = [(512, 16), (2048, 32), (4096, 64)]
VAR_BUCKETS_FULL = VAR_BUCKETS + [(4096, 128)]

# Batched session buckets: (n, d) panels fused b at a time. A small,
# deliberate set — every extra (n, d, b) cell is three more HLO
# artifacts, and the runtime rounds a fusion group up to the nearest
# covering (n, d, b) anyway (short groups pad with copies of panel 0).
BATCH_BUCKETS = [(256, 8), (1024, 16)]
BATCH_BUCKETS_FULL = BATCH_BUCKETS + [(4096, 32)]
BATCH_SIZES = [4, 8]

DTYPE = jnp.float32


def to_hlo_text(fn, *specs, return_tuple=True):
    """Lower a jax function at the given ShapeDtypeStructs to HLO text.

    ``return_tuple=False`` is for the single-output session artifacts:
    it leaves the root as the bare array so the PJRT output buffer can
    stay device-resident instead of being decomposed on the host.
    """
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def emit(out_dir, name, text, manifest, kind, n, d, b=None):
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        f.write(text)
    # batched kinds carry the batch size as a fifth manifest field
    fields = f"{kind} {n} {d} {name}" if b is None else f"{kind} {n} {d} {b} {name}"
    manifest.append(fields)
    print(f"  wrote {name}  ({len(text) / 1024:.0f} KiB)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--full", action="store_true", help="emit paper-scale buckets too")
    ap.add_argument(
        "--only",
        default=None,
        help="emit a single kind (order_scores|order_step|session|session_batch|var_fit)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    order_buckets = ORDER_BUCKETS_FULL if args.full else ORDER_BUCKETS
    var_buckets = VAR_BUCKETS_FULL if args.full else VAR_BUCKETS
    manifest = []

    for n, d in order_buckets:
        x = jax.ShapeDtypeStruct((n, d), DTYPE)
        rm = jax.ShapeDtypeStruct((n,), DTYPE)
        cm = jax.ShapeDtypeStruct((d,), DTYPE)
        if args.only in (None, "order_scores"):
            emit(
                args.out_dir,
                f"order_scores_n{n}_d{d}.hlo.txt",
                to_hlo_text(model.order_scores, x, rm, cm),
                manifest,
                "order_scores",
                n,
                d,
            )
        if args.only in (None, "order_step"):
            emit(
                args.out_dir,
                f"order_step_n{n}_d{d}.hlo.txt",
                to_hlo_text(model.order_step, x, rm, cm),
                manifest,
                "order_step",
                n,
                d,
            )
        if args.only in (None, "session"):
            # device-resident session kinds: single-array outputs, lowered
            # with a non-tuple root (see module docstring)
            state = jax.ShapeDtypeStruct(session_kernels.state_shape(n, d), DTYPE)
            for kind, fn, specs in [
                ("session_init", model.session_init, (x, rm, cm)),
                ("session_scores", model.session_scores, (state,)),
                ("session_update", model.session_update, (state, cm)),
            ]:
                emit(
                    args.out_dir,
                    f"{kind}_n{n}_d{d}.hlo.txt",
                    to_hlo_text(fn, *specs, return_tuple=False),
                    manifest,
                    kind,
                    n,
                    d,
                )

    batch_buckets = BATCH_BUCKETS_FULL if args.full else BATCH_BUCKETS
    for n, d in batch_buckets:
        if args.only in (None, "session_batch"):
            for b in BATCH_SIZES:
                xb = jax.ShapeDtypeStruct((b, n, d), DTYPE)
                rmb = jax.ShapeDtypeStruct((b, n), DTYPE)
                cmb = jax.ShapeDtypeStruct((b, d), DTYPE)
                state = jax.ShapeDtypeStruct(
                    (b,) + session_kernels.state_shape(n, d), DTYPE
                )
                for kind, fn, specs in [
                    ("session_init_batch", model.session_init_batch, (xb, rmb, cmb)),
                    ("session_scores_batch", model.session_scores_batch, (state,)),
                    ("session_update_batch", model.session_update_batch, (state, cmb)),
                ]:
                    emit(
                        args.out_dir,
                        f"{kind}_n{n}_d{d}_b{b}.hlo.txt",
                        to_hlo_text(fn, *specs, return_tuple=False),
                        manifest,
                        kind,
                        n,
                        d,
                        b=b,
                    )

    for t, d in var_buckets:
        if args.only in (None, "var_fit"):
            s = jax.ShapeDtypeStruct((t, d), DTYPE)
            rm = jax.ShapeDtypeStruct((t,), DTYPE)
            emit(
                args.out_dir,
                f"var_fit_t{t}_d{d}.hlo.txt",
                to_hlo_text(model.var_fit, s, rm),
                manifest,
                "var_fit",
                t,
                d,
            )

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(sorted(manifest)) + "\n")
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
