"""L1 correctness: the Pallas kernels against the pure-jnp oracle.

Hypothesis sweeps shapes, dtypes, masks and data distributions; every
case asserts allclose between `causal_order.residual_entropy_matrix` /
`residualize.residualize_panel` and their ref.py counterparts.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import causal_order, ref, residualize

hypothesis.settings.register_profile(
    "kernels", max_examples=25, deadline=None, derandomize=True
)
hypothesis.settings.load_profile("kernels")


def make_panel(n, d, n_valid, d_active, seed, dtype=np.float32, dist="uniform"):
    """Zero-padded panel with SEM-ish dependent columns + masks."""
    rng = np.random.default_rng(seed)
    if dist == "uniform":
        base = rng.uniform(0.0, 1.0, size=(n_valid, d))
    elif dist == "laplace":
        base = rng.laplace(0.0, 1.0, size=(n_valid, d))
    else:
        base = rng.normal(0.0, 1.0, size=(n_valid, d))
    # chain-like dependence so correlations are non-trivial
    for j in range(1, d):
        base[:, j] += 0.8 * base[:, j - 1]
    x = np.zeros((n, d), dtype=dtype)
    x[:n_valid, :] = base.astype(dtype)
    row_mask = np.zeros(n, dtype=dtype)
    row_mask[:n_valid] = 1.0
    col_mask = np.zeros(d, dtype=dtype)
    col_mask[:d_active] = 1.0
    # inactive columns zeroed (the Rust engine maintains this invariant)
    x[:, d_active:] = 0.0
    return jnp.asarray(x), jnp.asarray(row_mask), jnp.asarray(col_mask)


def tol(dtype):
    return dict(rtol=2e-4, atol=2e-4) if dtype == np.float32 else dict(rtol=1e-9, atol=1e-9)


def offdiag(m):
    """The HR diagonal is the degenerate self-pair (rho = 1, residual = 0/eps):
    catastrophic in f32 and *never consumed* (diff_ii = hr_ii - hr_ii = 0),
    so comparisons exclude it."""
    m = np.array(m, copy=True)
    np.fill_diagonal(m, 0.0)
    return m


# ---------------------------------------------------------------- HR kernel


@hypothesis.given(
    n=st.sampled_from([32, 64, 256]),
    d=st.sampled_from([4, 8, 16]),
    frac_valid=st.floats(0.3, 1.0),
    dist=st.sampled_from(["uniform", "laplace", "normal"]),
    seed=st.integers(0, 10_000),
)
def test_hr_kernel_matches_ref(n, d, frac_valid, dist, seed):
    n_valid = max(8, int(n * frac_valid))
    x, rm, cm = make_panel(n, d, n_valid, d, seed, dist=dist)
    xs, nv = ref.masked_standardize(x, rm, cm)
    rho = xs.T @ xs / nv
    got = causal_order.residual_entropy_matrix(xs, rho, nv)
    want = ref.residual_entropy_matrix_ref(xs, rho, nv)
    np.testing.assert_allclose(offdiag(got), offdiag(want), **tol(np.float32))


@hypothesis.given(
    block_j=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 1000),
)
def test_hr_kernel_blocking_invariant(block_j, seed):
    """Tiling must not change the result (VMEM schedule is semantics-free)."""
    x, rm, cm = make_panel(128, 8, 100, 8, seed)
    xs, nv = ref.masked_standardize(x, rm, cm)
    rho = xs.T @ xs / nv
    full = causal_order.residual_entropy_matrix(xs, rho, nv)
    tiled = causal_order.residual_entropy_matrix(xs, rho, nv, block_j=block_j)
    np.testing.assert_allclose(np.asarray(full), np.asarray(tiled), rtol=1e-6, atol=1e-6)


def test_hr_diagonal_never_reaches_scores():
    """Self-pairs are degenerate but cancel: diff_ii = 0 exactly, so the
    diagonal can never contribute to k_list."""
    x, rm, cm = make_panel(64, 4, 64, 4, 3)
    xs, nv = ref.masked_standardize(x, rm, cm)
    rho = xs.T @ xs / nv
    hr = np.asarray(causal_order.residual_entropy_matrix(xs, rho, nv))
    h = np.asarray(ref.column_entropies(xs, nv))
    diff = (h[None, :] + hr) - (h[:, None] + hr.T)
    np.testing.assert_array_equal(np.diag(diff), 0.0)


def test_hr_kernel_f64():
    """dtype sweep: float64 path agrees with the oracle tightly."""
    x, rm, cm = make_panel(128, 8, 100, 8, 7, dtype=np.float64)
    xs, nv = ref.masked_standardize(x, rm, cm)
    rho = xs.T @ xs / nv
    got = causal_order.residual_entropy_matrix(xs, rho, nv)
    want = ref.residual_entropy_matrix_ref(xs, rho, nv)
    assert got.dtype == jnp.float64 or not jax.config.jax_enable_x64
    np.testing.assert_allclose(offdiag(got), offdiag(want), rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------- residualize


@hypothesis.given(
    n=st.sampled_from([32, 128]),
    d=st.sampled_from([4, 8]),
    m=st.integers(0, 7),
    seed=st.integers(0, 1000),
)
def test_residualize_kernel_matches_ref(n, d, m, seed):
    m = m % d
    x, rm, cm = make_panel(n, d, n - 5, d, seed)
    onehot = jnp.zeros(d, dtype=x.dtype).at[m].set(1.0)
    want = ref.residualize_ref(x, rm, cm, onehot)

    # drive the pallas kernel exactly the way model.order_step does
    rmc = rm[:, None]
    nv = jnp.maximum(jnp.sum(rm), 1.0)
    mean = jnp.sum(x * rmc, axis=0) / nv
    centered = (x - mean[None, :]) * rmc
    xm = centered @ onehot
    var_m = jnp.maximum(jnp.sum(xm * xm) / nv, 1e-30)
    beta = (centered.T @ xm) / nv / var_m
    keep = cm * (1.0 - onehot)
    got = residualize.residualize_panel(centered, xm, beta, keep)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_residualize_kills_correlation():
    x, rm, cm = make_panel(256, 6, 200, 6, 11)
    onehot = jnp.zeros(6, dtype=x.dtype).at[2].set(1.0)
    out = np.asarray(ref.residualize_ref(x, rm, cm, onehot))
    xm = np.asarray(x)[:200, 2] - np.asarray(x)[:200, 2].mean()
    for j in [0, 1, 3, 4, 5]:
        c = np.abs(np.corrcoef(out[:200, j], xm)[0, 1])
        assert c < 1e-3, f"col {j} corr {c}"
    # chosen column zeroed, padded rows zeroed
    assert np.all(out[:, 2] == 0.0)
    assert np.all(out[200:, :] == 0.0)


def test_residualize_preserves_padding_invariant():
    x, rm, cm = make_panel(64, 4, 40, 3, 13)  # one inactive column
    onehot = jnp.zeros(4, dtype=x.dtype).at[0].set(1.0)
    out = np.asarray(ref.residualize_ref(x, rm, cm, onehot))
    assert np.all(out[40:, :] == 0.0)  # padding
    assert np.all(out[:, 3] == 0.0)  # inactive column stays zero


# Degenerate-panel guard tests (rho^2-clamp, NaN-safe argmax) live in
# test_degenerate.py: that file is deliberately hypothesis-free so it
# runs in environments where `hypothesis` is unavailable (this module
# imports it at the top and is skipped wholesale there).
