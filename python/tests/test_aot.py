"""AOT emission sanity: the HLO-text artifacts are well-formed, the
manifest matches the files on disk, and the interchange constraints the
Rust loader relies on hold (ENTRY computation present, tuple root,
expected parameter shapes)."""

import os
import re
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
ART = os.path.join(REPO, "artifacts")


def parse_row(fields):
    """A manifest row: ``kind n d name`` or ``kind n d b name`` for the
    batched kinds (the same 4-or-5-field grammar the Rust registry
    parses). Returns (kind, n, d, b_or_None, name)."""
    if len(fields) == 4:
        kind, n, d, name = fields
        return kind, int(n), int(d), None, name
    kind, n, d, b, name = fields
    return kind, int(n), int(d), int(b), name


@pytest.fixture(scope="module")
def artifacts():
    manifest = os.path.join(ART, "manifest.txt")
    if not os.path.exists(manifest):
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", ART],
            cwd=os.path.join(REPO, "python"),
            check=True,
        )
    with open(manifest) as f:
        lines = [parse_row(l.split()) for l in f.read().splitlines() if l.strip()]
    return lines


TUPLE_KINDS = {"order_scores", "order_step", "var_fit"}
SESSION_KINDS = {"session_init", "session_scores", "session_update"}
BATCH_KINDS = {"session_init_batch", "session_scores_batch", "session_update_batch"}


def test_manifest_entries_exist_and_unique(artifacts):
    assert len(artifacts) >= 10
    names = [row[4] for row in artifacts]
    assert len(set(names)) == len(names), "duplicate artifact names"
    for kind, n, d, b, name in artifacts:
        assert kind in TUPLE_KINDS | SESSION_KINDS | BATCH_KINDS
        assert n > 0 and d > 0
        # the fifth field is present exactly for the batched kinds
        assert (b is not None) == (kind in BATCH_KINDS), f"{name}: field count"
        if b is not None:
            assert b > 1
        path = os.path.join(ART, name)
        assert os.path.exists(path), f"missing {name}"
        assert os.path.getsize(path) > 1_000, f"{name} suspiciously small"


def test_session_kinds_cover_every_order_bucket(artifacts):
    """The device-resident session needs all three kinds at one shape;
    the Rust XlaSession refuses a bucket where any of them is missing."""
    order = {(n, d) for kind, n, d, _, _ in artifacts if kind == "order_step"}
    for kind in SESSION_KINDS:
        have = {(n, d) for k, n, d, _, _ in artifacts if k == kind}
        assert have == order, f"{kind} buckets {have} != order buckets {order}"


def test_batch_kinds_cover_the_same_cells(artifacts):
    """All three batched kinds must exist at every (n, d, b) cell — the
    Rust XlaBatchSession needs the full triple, same as the solo
    session — and each batch bucket's (n, d) must also exist solo (the
    singleton fallback path)."""
    cells = {(n, d, b) for k, n, d, b, _ in artifacts if k == "session_init_batch"}
    assert cells, "no batched session buckets emitted"
    for kind in BATCH_KINDS:
        have = {(n, d, b) for k, n, d, b, _ in artifacts if k == kind}
        assert have == cells, f"{kind} cells {have} != init cells {cells}"
    solo = {(n, d) for k, n, d, _, _ in artifacts if k == "session_init"}
    assert {(n, d) for n, d, _ in cells} <= solo


def test_hlo_text_is_parsable_shape(artifacts):
    for kind, n, d, b, name in artifacts:
        text = open(os.path.join(ART, name)).read()
        assert "ENTRY" in text, f"{name}: no ENTRY computation"
        # the entry output signature lives in entry_computation_layout on
        # the first line: `->(...)` is a tuple root, `->f32[...]` a bare
        # array (sub-computations like fori_loop bodies have tuple ROOTs
        # of their own, so grepping ROOT lines would misclassify)
        sig = text.splitlines()[0].replace(" ", "")
        if kind in TUPLE_KINDS:
            # tuple root (return_tuple=True contract: the loader
            # downloads and decomposes it on the host)
            assert "->(" in sig, f"{name}: non-tuple entry output: {sig}"
        else:
            # session kinds must have a bare-array root: that is what
            # lets the runtime keep the output buffer device-resident
            assert "->f32[" in sig and "->(" not in sig, (
                f"{name}: tuple entry output: {sig}"
            )
        # declared parameter shape matches the bucket
        if kind in ("order_scores", "order_step", "session_init"):
            assert f"f32[{n},{d}]" in text, f"{name}: missing panel param shape"
            assert f"f32[{n}]" in text and f"f32[{d}]" in text, f"{name}: missing masks"
        if kind == "session_init_batch":
            assert f"f32[{b},{n},{d}]" in text, f"{name}: missing panel batch shape"
            assert f"f32[{b},{n}]" in text and f"f32[{b},{d}]" in text, (
                f"{name}: missing batched masks"
            )
        if kind in SESSION_KINDS:
            nd = n + d + 2  # packed state rows (session.META_ROWS)
            assert f"f32[{nd},{d}]" in text, f"{name}: missing packed state shape"
        if kind in BATCH_KINDS:
            nd = n + d + 2
            assert f"f32[{b},{nd},{d}]" in text, (
                f"{name}: missing batched packed state shape"
            )


def test_no_custom_calls(artifacts):
    """xla_extension 0.5.1 cannot run typed-FFI custom-calls (LAPACK etc.);
    every artifact must lower to plain HLO (the Newton-Schulz / pallas-
    interpret design constraint)."""
    for _, _, _, _, name in artifacts:
        text = open(os.path.join(ART, name)).read()
        assert "custom-call" not in text, f"{name} contains a custom-call"


def test_filename_matches_manifest_row(artifacts):
    for kind, n, d, b, name in artifacts:
        if kind == "var_fit":
            assert name == f"var_fit_t{n}_d{d}.hlo.txt"
        elif kind in BATCH_KINDS:
            assert name == f"{kind}_n{n}_d{d}_b{b}.hlo.txt"
        else:
            assert name == f"{kind}_n{n}_d{d}.hlo.txt"


def test_session_init_output_is_packed_state_shape(artifacts):
    """entry_computation_layout pins the init output to [N+D+2, D] —
    the packed layout the Rust XlaSession threads between steps
    ([B, N+D+2, D] for the batched variant)."""
    for kind, n, d, b, name in artifacts:
        if kind not in ("session_init", "session_init_batch"):
            continue
        first = open(os.path.join(ART, name)).readline()
        nd = n + d + 2
        want = f"->f32[{nd},{d}]" if b is None else f"->f32[{b},{nd},{d}]"
        assert want in first.replace(" ", ""), (
            f"{name}: init output is not the packed state: {first.strip()}"
        )
