"""AOT emission sanity: the HLO-text artifacts are well-formed, the
manifest matches the files on disk, and the interchange constraints the
Rust loader relies on hold (ENTRY computation present, tuple root,
expected parameter shapes)."""

import os
import re
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
ART = os.path.join(REPO, "artifacts")


@pytest.fixture(scope="module")
def artifacts():
    manifest = os.path.join(ART, "manifest.txt")
    if not os.path.exists(manifest):
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", ART],
            cwd=os.path.join(REPO, "python"),
            check=True,
        )
    with open(manifest) as f:
        lines = [l.split() for l in f.read().splitlines() if l.strip()]
    return lines


def test_manifest_entries_exist_and_unique(artifacts):
    assert len(artifacts) >= 10
    names = [row[3] for row in artifacts]
    assert len(set(names)) == len(names), "duplicate artifact names"
    for kind, n, d, name in artifacts:
        assert kind in {"order_scores", "order_step", "var_fit"}
        assert int(n) > 0 and int(d) > 0
        path = os.path.join(ART, name)
        assert os.path.exists(path), f"missing {name}"
        assert os.path.getsize(path) > 1_000, f"{name} suspiciously small"


def test_hlo_text_is_parsable_shape(artifacts):
    for kind, n, d, name in artifacts[:6]:
        text = open(os.path.join(ART, name)).read()
        assert "ENTRY" in text, f"{name}: no ENTRY computation"
        # root must be a tuple (return_tuple=True contract with the loader)
        assert re.search(r"ROOT\s+\S+\s*=\s*\(", text), f"{name}: non-tuple root"
        # declared parameter shape matches the bucket
        if kind in ("order_scores", "order_step"):
            assert f"f32[{n},{d}]" in text, f"{name}: missing panel param shape"
            assert f"f32[{n}]" in text and f"f32[{d}]" in text, f"{name}: missing masks"


def test_no_custom_calls(artifacts):
    """xla_extension 0.5.1 cannot run typed-FFI custom-calls (LAPACK etc.);
    every artifact must lower to plain HLO (the Newton-Schulz / pallas-
    interpret design constraint)."""
    for _, _, _, name in artifacts:
        text = open(os.path.join(ART, name)).read()
        assert "custom-call" not in text, f"{name} contains a custom-call"


def test_filename_matches_manifest_row(artifacts):
    for kind, n, d, name in artifacts:
        if kind == "var_fit":
            assert name == f"var_fit_t{n}_d{d}.hlo.txt"
        else:
            assert name == f"{kind}_n{n}_d{d}.hlo.txt"
