"""L2 correctness: order_scores / order_step / var_fit semantics.

Checks Algorithm-1-level behaviour (the right variable wins on known
causal structures), the fused-step composition, masking semantics, and
the VAR fit against numpy lstsq.
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref

hypothesis.settings.register_profile(
    "model", max_examples=15, deadline=None, derandomize=True
)
hypothesis.settings.load_profile("model")


def chain_data(n, d, seed, theta=1.2):
    """x_0 -> x_1 -> ... with uniform noise: causal order = identity."""
    rng = np.random.default_rng(seed)
    x = np.zeros((n, d), dtype=np.float32)
    x[:, 0] = rng.uniform(size=n)
    for j in range(1, d):
        x[:, j] = theta * x[:, j - 1] + rng.uniform(size=n)
    return jnp.asarray(x)


def masks(n, d, n_valid=None, dtype=jnp.float32):
    rm = np.zeros(n, dtype=np.float32)
    rm[: (n_valid or n)] = 1.0
    return jnp.asarray(rm), jnp.ones(d, dtype=dtype)


def test_scores_pick_root_of_chain():
    x = chain_data(4096, 6, 0)
    rm, cm = masks(4096, 6)
    k = np.asarray(model.order_scores(x, rm, cm))
    assert int(np.argmax(k)) == 0, k


def test_scores_match_ref_oracle():
    x = chain_data(512, 8, 1)
    rm, cm = masks(512, 8)
    got = np.asarray(model.order_scores(x, rm, cm))
    want = np.asarray(ref.order_scores_ref(x, rm, cm))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@hypothesis.given(seed=st.integers(0, 500), n_valid=st.sampled_from([300, 512]))
def test_padding_does_not_change_scores(seed, n_valid):
    """Zero-padding rows + row mask must equal the unpadded computation."""
    x_small = chain_data(n_valid, 6, seed)
    rm_s, cm = masks(n_valid, 6)
    k_small = np.asarray(model.order_scores(x_small, rm_s, cm))

    x_pad = jnp.zeros((1024, 6), dtype=x_small.dtype).at[:n_valid].set(x_small)
    rm_p, _ = masks(1024, 6, n_valid)
    k_pad = np.asarray(model.order_scores(x_pad, rm_p, cm))
    np.testing.assert_allclose(k_small, k_pad, rtol=2e-3, atol=2e-3)


@hypothesis.given(seed=st.integers(0, 500))
def test_inactive_columns_excluded(seed):
    """Masking a column must equal physically removing it (up to layout)."""
    d = 6
    x = chain_data(512, d, seed)
    rm, cm = masks(512, d)
    cm = cm.at[3].set(0.0)
    x_masked = x.at[:, 3].set(0.0)
    k = np.asarray(model.order_scores(x_masked, rm, cm))
    assert k[3] == ref.INACTIVE
    # compare against a panel where column 3 is truly absent
    keep = [0, 1, 2, 4, 5]
    x_sub = x[:, keep]
    rm2, cm2 = masks(512, 5)
    k_sub = np.asarray(model.order_scores(x_sub, rm2, cm2))
    np.testing.assert_allclose(k[keep], k_sub, rtol=2e-3, atol=2e-3)


def test_order_step_full_iteration_matches_ref():
    x = chain_data(512, 6, 3)
    rm, cm = masks(512, 6)
    x1, m, k = model.order_step(x, rm, cm)
    x1r, mr, kr = ref.order_step_ref(x, rm, cm)
    assert int(m) == int(mr)
    np.testing.assert_allclose(np.asarray(k), np.asarray(kr), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x1r), rtol=2e-3, atol=2e-3)


def test_iterated_steps_recover_chain_order():
    d = 5
    x = chain_data(4096, d, 4)
    rm, cm = masks(4096, d)
    order = []
    for _ in range(d - 1):
        x, m, _ = model.order_step(x, rm, cm)
        m = int(m)
        order.append(m)
        cm = cm.at[m].set(0.0)
    order.append(int(np.argmax(np.asarray(cm))))
    assert order == [0, 1, 2, 3, 4], order


def test_var_fit_matches_numpy():
    rng = np.random.default_rng(0)
    d, t = 4, 2000
    m1_true = 0.3 * rng.standard_normal((d, d))
    x = np.zeros((t, d), dtype=np.float32)
    for tt in range(1, t):
        x[tt] = m1_true @ x[tt - 1] + rng.laplace(size=d)
    rm = jnp.ones(t, dtype=jnp.float32)
    m1, resid = model.var_fit(jnp.asarray(x), rm)
    m1 = np.asarray(m1)
    np.testing.assert_allclose(m1, m1_true, atol=0.08)
    # residuals should be uncorrelated with the past
    r = np.asarray(resid)
    past = x[:-1] - x[:-1].mean(0)
    cross = np.abs(past.T @ r) / t
    assert cross.max() < 0.1, cross.max()


def test_var_fit_masked_equals_truncated():
    rng = np.random.default_rng(1)
    x = rng.standard_normal((256, 4)).astype(np.float32)
    x[200:] = 0.0
    rm = np.zeros(256, dtype=np.float32)
    rm[:200] = 1.0
    m1_pad, _ = model.var_fit(jnp.asarray(x), jnp.asarray(rm))
    m1_cut, _ = model.var_fit(
        jnp.asarray(x[:200]), jnp.ones(200, dtype=jnp.float32)
    )
    np.testing.assert_allclose(np.asarray(m1_pad), np.asarray(m1_cut), rtol=1e-3, atol=1e-4)
