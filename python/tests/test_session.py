"""Host-mirror tests of the device-resident session kernels
(compile/kernels/session.py): per-step agreement with the from-scratch
stateless reference, state-invariant preservation, and the degenerate
panels the rho^2-clamp hardening covers.

Deliberately hypothesis-free (same policy as test_degenerate.py): these
guards must run everywhere the jax stack exists.
"""

import jax.numpy as jnp
import numpy as np

from compile.kernels import ref, session


def make_panel(n, d, n_valid, seed, coupling=0.7):
    """Zero-padded panel with chain-dependent columns + masks."""
    rng = np.random.default_rng(seed)
    base = rng.uniform(0.0, 1.0, size=(n_valid, d))
    for j in range(1, d):
        base[:, j] += coupling * base[:, j - 1]
    x = np.zeros((n, d), dtype=np.float32)
    x[:n_valid, :] = base.astype(np.float32)
    row_mask = np.zeros(n, dtype=np.float32)
    row_mask[:n_valid] = 1.0
    col_mask = np.ones(d, dtype=np.float32)
    return jnp.asarray(x), jnp.asarray(row_mask), jnp.asarray(col_mask)


def test_state_layout_roundtrip():
    x, rm, cm = make_panel(64, 4, 50, 1)
    state = session.session_init(x, rm, cm)
    assert state.shape == session.state_shape(64, 4)
    xs, rho, col_mask, n_valid = session.unpack_state(state)
    assert xs.shape == (64, 4) and rho.shape == (4, 4)
    assert float(n_valid) == 50.0
    np.testing.assert_array_equal(np.asarray(col_mask), np.ones(4, np.float32))
    # cache rows beyond n_valid are exactly 0 (masked-standardize invariant)
    assert np.all(np.asarray(xs)[50:] == 0.0)
    # correlation diagonal ~ 1 on active block
    np.testing.assert_allclose(np.diag(np.asarray(rho)), 1.0, atol=1e-5)


def test_first_scores_match_stateless_exactly():
    # before any update the session runs the same masked standardize +
    # correlation matmul as order_scores_ref: near-bitwise agreement
    x, rm, cm = make_panel(128, 8, 100, 2)
    state = session.session_init(x, rm, cm)
    k_sess = np.asarray(session.session_scores(state))
    k_ref = np.asarray(ref.order_scores_ref(x, rm, cm))
    np.testing.assert_allclose(k_sess, k_ref, rtol=1e-6, atol=1e-6)


def test_session_agrees_with_stateless_reference_per_step():
    # the tentpole contract: the resident workspace (closed-form cache
    # residualization + analytic correlation update) reproduces the
    # from-scratch order_step_ref choice at every step, and its score
    # rows agree to f32 precision
    n, d = 256, 8
    x, rm, cm = make_panel(n, d, 200, 3)
    state = session.session_init(x, rm, cm)
    xr, cmr = x, cm
    for step in range(d - 1):
        k_sess = np.asarray(session.session_scores(state))
        k_ref = np.asarray(ref.order_scores_ref(xr, rm, cmr))
        active = np.asarray(cmr) > 0
        rel = np.max(
            np.abs(k_sess - k_ref)[active] / (1.0 + np.abs(k_ref[active]))
        )
        assert rel < 1e-5, f"step {step}: score drift {rel}"
        state, m_sess, _ = session.session_step_host(state)
        xr, m_ref, _ = ref.order_step_ref(xr, rm, cmr)
        assert int(m_sess) == int(m_ref), f"step {step}: choice diverged"
        cmr = cmr.at[int(m_ref)].set(0.0)


def test_update_preserves_state_invariants():
    # after an update: chosen column zeroed everywhere, padded rows still
    # zero, active diagonal exactly 1, correlations clamped to [-1, 1]
    x, rm, cm = make_panel(96, 6, 80, 4)
    state = session.session_init(x, rm, cm)
    state, m, _ = session.session_step_host(state)
    m = int(m)
    xs, rho, col_mask, n_valid = session.unpack_state(state)
    xs, rho, col_mask = map(np.asarray, (xs, rho, col_mask))
    assert col_mask[m] == 0.0 and col_mask.sum() == 5.0
    assert np.all(xs[:, m] == 0.0) and np.all(rho[m, :] == 0.0)
    assert np.all(xs[80:, :] == 0.0), "padded rows drifted from 0"
    assert np.all(np.abs(rho) <= 1.0)
    for j in range(6):
        if j != m:
            assert rho[j, j] == 1.0, f"active diagonal drifted: rho[{j},{j}]"
    # remaining active cache columns are re-standardized: mean 0, var 1
    act = [j for j in range(6) if j != m]
    means = xs[:, act].sum(axis=0) / 80.0
    var = (xs[:, act] ** 2).sum(axis=0) / 80.0
    np.testing.assert_allclose(means, 0.0, atol=1e-4)
    np.testing.assert_allclose(var, 1.0, atol=1e-3)


def test_exhaustion_runs_to_last_variable():
    # driving d-1 steps leaves exactly one active variable and every
    # score row along the way finite on the active set
    x, rm, cm = make_panel(64, 5, 60, 5)
    state = session.session_init(x, rm, cm)
    for _ in range(4):
        k = np.asarray(session.session_scores(state))
        active = np.asarray(session.unpack_state(state)[2]) > 0
        assert np.all(np.isfinite(k[active]))
        state, _, _ = session.session_step_host(state)
    assert float(np.asarray(session.unpack_state(state)[2]).sum()) == 1.0


def test_degenerate_duplicated_column_stays_finite():
    # column 3 duplicates column 1 (rho -> 1): the shared rho^2-clamp
    # must keep both the scores and the updated state finite, and the
    # elected variable must be active — mirroring test_degenerate.py on
    # the session path
    x, rm, cm = make_panel(128, 8, 100, 17)
    x = x.at[:, 3].set(x[:, 1])
    state = session.session_init(x, rm, cm)
    for step in range(7):
        col_mask = np.asarray(session.unpack_state(state)[2])
        state, m, k = session.session_step_host(state)
        m = int(m)
        assert col_mask[m] == 1.0, f"step {step}: inactive choice {m}"
        assert not np.any(np.isnan(np.asarray(k))), f"step {step}: NaN k_list"
        assert np.all(np.isfinite(np.asarray(state))), f"step {step}: state inf"


def test_batched_kinds_are_bitwise_the_solo_kinds():
    # the fusion-window contract: every slice of the vmapped batch
    # artifacts equals the solo artifact's output bit for bit, through a
    # full multi-step drive with per-panel choices diverging
    n, d, b = 128, 6, 4
    panels = [make_panel(n, d, 100 - 7 * i, 20 + i) for i in range(b)]
    xb = jnp.stack([p[0] for p in panels])
    rmb = jnp.stack([p[1] for p in panels])
    cmb = jnp.stack([p[2] for p in panels])
    state_b = session.session_init_batch(xb, rmb, cmb)
    states = [session.session_init(*p) for p in panels]
    for i in range(b):
        np.testing.assert_array_equal(np.asarray(state_b[i]), np.asarray(states[i]))
    for step in range(d - 1):
        k_b = session.session_scores_batch(state_b)
        onehots = []
        for i in range(b):
            k_solo = session.session_scores(states[i])
            np.testing.assert_array_equal(
                np.asarray(k_b[i]), np.asarray(k_solo), err_msg=f"step {step} panel {i}"
            )
            m = ref.safe_argmax(k_solo)
            oh = jnp.zeros((d,), jnp.float32).at[m].set(1.0)
            onehots.append(oh)
            states[i] = session.session_update(states[i], oh)
        state_b = session.session_update_batch(state_b, jnp.stack(onehots))
        for i in range(b):
            np.testing.assert_array_equal(
                np.asarray(state_b[i]),
                np.asarray(states[i]),
                err_msg=f"step {step} panel {i}",
            )


def test_batched_all_zero_onehot_is_a_lane_noop():
    # dropped/finished lanes ride along as all-zero one-hots: the lane's
    # masks are untouched and its cache/correlations stay bitwise fixed
    x, rm, cm = make_panel(96, 5, 80, 31)
    state = session.session_init_batch(x[None], rm[None], cm[None])
    stepped = session.session_update_batch(state, jnp.zeros((1, 5), jnp.float32))
    before = session.unpack_state(state[0])
    after = session.unpack_state(stepped[0])
    np.testing.assert_array_equal(np.asarray(after[0]), np.asarray(before[0]))
    np.testing.assert_array_equal(np.asarray(after[2]), np.asarray(before[2]))
    assert float(after[3]) == float(before[3])


def test_inactive_columns_score_inactive():
    x, rm, cm = make_panel(64, 6, 50, 6)
    cm = cm.at[2].set(0.0)
    state = session.session_init(x, rm, cm)
    k = np.asarray(session.session_scores(state))
    assert k[2] == np.float32(ref.INACTIVE)
    assert np.all(np.isfinite(k[[0, 1, 3, 4, 5]]))
