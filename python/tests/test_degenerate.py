"""Degenerate-panel hardening of the XLA/Pallas path (mirrors the Rust
degenerate-panel suite): the rho^2-clamp before the residual denominator
and the NaN-safe on-device argmax of the fused ``order_step``.

Deliberately hypothesis-free: ``test_kernel.py``/``test_model.py`` import
`hypothesis` at module scope and are skipped wholesale where it is not
installed; these guards must run everywhere the jax stack exists.
"""

import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import causal_order, ref


def make_panel(n, d, n_valid, seed):
    """Zero-padded panel with chain-dependent columns + masks."""
    rng = np.random.default_rng(seed)
    base = rng.uniform(0.0, 1.0, size=(n_valid, d))
    for j in range(1, d):
        base[:, j] += 0.8 * base[:, j - 1]
    x = np.zeros((n, d), dtype=np.float32)
    x[:n_valid, :] = base.astype(np.float32)
    row_mask = np.zeros(n, dtype=np.float32)
    row_mask[:n_valid] = 1.0
    col_mask = np.ones(d, dtype=np.float32)
    return jnp.asarray(x), jnp.asarray(row_mask), jnp.asarray(col_mask)


def duplicated_panel(n=128, d=8, n_valid=100, seed=17):
    """Panel whose column 3 exactly duplicates column 1 (rho -> 1)."""
    x, rm, cm = make_panel(n, d, n_valid, seed)
    x = x.at[:, 3].set(x[:, 1])
    return x, rm, cm


def offdiag(m):
    """Self-pairs are degenerate by construction and never consumed
    (diff_ii == 0), so finiteness checks exclude the diagonal."""
    m = np.array(m, copy=True)
    np.fill_diagonal(m, 0.0)
    return m


def test_rho_clamp_keeps_hr_finite_on_duplicated_columns():
    # an exactly-duplicated column drives rho^2 to (or past) 1 in f32;
    # the clamped kernel and oracle must both stay finite off-diagonal
    x, rm, cm = duplicated_panel()
    xs, nv = ref.masked_standardize(x, rm, cm)
    rho = xs.T @ xs / nv
    for hr in [
        causal_order.residual_entropy_matrix(xs, rho, nv),
        ref.residual_entropy_matrix_ref(xs, rho, nv),
    ]:
        assert np.all(np.isfinite(offdiag(hr))), "HR went non-finite on rho ~ 1"


def test_order_scores_finite_on_duplicated_columns():
    x, rm, cm = duplicated_panel()
    k = np.asarray(ref.order_scores_ref(x, rm, cm))
    assert not np.any(np.isnan(k)), f"NaN k_list on duplicated columns: {k}"


def test_order_step_argmax_is_nan_safe():
    # direct guard check: NaN scores must never win the fused step's argmax
    k = jnp.asarray([np.nan, 1.0, np.nan, 0.5, ref.INACTIVE])
    assert int(ref.safe_argmax(k)) == 1
    all_bad = jnp.asarray([np.nan, np.nan])
    # every score NaN: all rewrite to INACTIVE; any index is acceptable —
    # the property is that the argmax is computable without NaN poisoning
    # (the Rust host side then rejects the NaN-scored choice)
    assert int(ref.safe_argmax(all_bad)) in (0, 1)


def test_order_step_on_duplicated_panel_elects_valid_variable():
    x, rm, cm = duplicated_panel()
    x_next, m, k_list = model.order_step(x, rm, cm)
    m = int(m)
    assert 0 <= m < x.shape[1] and float(cm[m]) == 1.0, f"invalid choice {m}"
    assert not np.any(np.isnan(np.asarray(k_list)))
    assert np.all(np.isfinite(np.asarray(x_next)))


def test_order_step_refs_agree_on_degenerate_panel():
    # the oracle's fused step and the L2 graph's fused step pick the same
    # variable on the degenerate panel
    x, rm, cm = duplicated_panel(seed=23)
    assert int(ref.order_step_ref(x, rm, cm)[1]) == int(model.order_step(x, rm, cm)[1])


def test_residual_denom_matches_rust_clamp_semantics():
    # rho slightly past 1 (f32 rounding of collinear columns): the clamp
    # must zero the variance term, not produce sqrt of a negative
    rho = jnp.asarray([0.0, 0.5, 1.0, 1.0000001, -1.0000001])
    d = np.asarray(ref.residual_denom(rho))
    assert np.all(np.isfinite(d)) and np.all(d > 0.0)
    np.testing.assert_allclose(d[0], 1.0, rtol=1e-6)
    np.testing.assert_allclose(d[1], np.sqrt(0.75), rtol=1e-6)
    assert d[2] == d[3] == d[4] == np.float32(np.sqrt(ref.DENOM_EPS))
