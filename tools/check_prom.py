#!/usr/bin/env python3
"""Validate a Prometheus text-format (version 0.0.4) exposition.

CI pipes the serve tier's ``GET /metrics?format=prometheus`` output
through this checker after the smoke fits, so a malformed rendering (bad
escaping, samples before their ``# TYPE``, duplicate families, garbage
values) fails the build instead of silently breaking scrapes::

    curl -sf 'http://127.0.0.1:PORT/metrics?format=prometheus' \\
        | python3 tools/check_prom.py --require alingam_job_latency_seconds

Checks, per the exposition-format spec:

* metric names match ``[a-zA-Z_:][a-zA-Z0-9_:]*``, label names match
  ``[a-zA-Z_][a-zA-Z0-9_]*``;
* label values are well-formed quoted strings (``\\\\``, ``\\"`` and
  ``\\n`` escapes only);
* sample values parse as floats (``NaN``, ``+Inf`` and ``-Inf``
  included);
* every sample's family has a ``# TYPE`` line *before* it, of a valid
  type (``counter``/``gauge``/``summary``/``histogram``/``untyped``),
  and no family declares ``# TYPE`` twice — summaries may suffix the
  family name with ``_sum``/``_count`` (histograms also ``_bucket``);
* each ``--require NAME`` (repeatable) names a family that must be
  present *with at least one sample*.

Stdlib only — no third-party dependencies. Exits non-zero with a
line-numbered message on the first violation.
"""

from __future__ import annotations

import argparse
import re
import sys

METRIC_NAME = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
LABEL_NAME = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")
VALID_TYPES = {"counter", "gauge", "summary", "histogram", "untyped"}
# suffixes that attach a sample to a base family declared by # TYPE
FAMILY_SUFFIXES = ("_bucket", "_sum", "_count", "_max")


class FormatError(Exception):
    """A violation, carrying the 1-based line number."""

    def __init__(self, lineno: int, message: str):
        super().__init__(f"line {lineno}: {message}")


def parse_labels(lineno: int, raw: str) -> None:
    """Validate the inside of a ``{...}`` label block."""
    i, n = 0, len(raw)
    while i < n:
        m = re.match(r"[a-zA-Z_][a-zA-Z0-9_]*", raw[i:])
        if not m:
            raise FormatError(lineno, f"bad label name at {raw[i:]!r}")
        i += m.end()
        if i >= n or raw[i] != "=":
            raise FormatError(lineno, "label name not followed by '='")
        i += 1
        if i >= n or raw[i] != '"':
            raise FormatError(lineno, "label value must be quoted")
        i += 1
        while i < n and raw[i] != '"':
            if raw[i] == "\\":
                if i + 1 >= n or raw[i + 1] not in ('\\', '"', "n"):
                    raise FormatError(lineno, f"bad escape in label value: {raw[i:i+2]!r}")
                i += 2
            else:
                i += 1
        if i >= n:
            raise FormatError(lineno, "unterminated label value")
        i += 1  # closing quote
        if i < n:
            if raw[i] != ",":
                raise FormatError(lineno, f"expected ',' between labels, got {raw[i]!r}")
            i += 1


def parse_value(lineno: int, token: str) -> float:
    if token in ("NaN", "+Inf", "-Inf", "Inf"):
        return float(token.replace("Inf", "inf"))
    try:
        return float(token)
    except ValueError:
        raise FormatError(lineno, f"bad sample value {token!r}") from None


def base_family(name: str, typed: dict[str, str]) -> str:
    """Resolve a sample name to its ``# TYPE``-declared family."""
    if name in typed:
        return name
    for suffix in FAMILY_SUFFIXES:
        if name.endswith(suffix) and name[: -len(suffix)] in typed:
            return name[: -len(suffix)]
    return name


def check(text: str, required: list[str]) -> None:
    typed: dict[str, str] = {}
    sampled: set[str] = set()
    for lineno, line in enumerate(text.splitlines(), start=1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4:
                raise FormatError(lineno, "TYPE line must be '# TYPE <name> <type>'")
            _, _, name, mtype = parts
            if not METRIC_NAME.match(name):
                raise FormatError(lineno, f"bad metric name {name!r}")
            if mtype not in VALID_TYPES:
                raise FormatError(lineno, f"bad metric type {mtype!r}")
            if name in typed:
                raise FormatError(lineno, f"duplicate TYPE for {name!r}")
            if name in sampled:
                raise FormatError(lineno, f"TYPE for {name!r} after its samples")
            typed[name] = mtype
            continue
        if line.startswith("# HELP "):
            parts = line.split(maxsplit=3)
            if len(parts) < 3 or not METRIC_NAME.match(parts[2]):
                raise FormatError(lineno, "HELP line must be '# HELP <name> <text>'")
            continue
        if line.startswith("#"):
            continue  # free-form comment
        # sample: name[{labels}] value [timestamp]
        m = re.match(r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{([^}]*)\})?\s+(\S+)(\s+-?\d+)?\s*$", line)
        if not m:
            raise FormatError(lineno, f"unparseable sample line {line!r}")
        name, _, labels, value, _ = m.groups()
        if labels:
            parse_labels(lineno, labels)
        parse_value(lineno, value)
        family = base_family(name, typed)
        if family not in typed:
            raise FormatError(lineno, f"sample {name!r} has no preceding # TYPE")
        sampled.add(family)
    missing = [r for r in required if r not in sampled]
    if missing:
        raise FormatError(0, f"required families absent or sample-less: {', '.join(missing)}")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("path", nargs="?", help="exposition file (default: stdin)")
    ap.add_argument(
        "--require",
        action="append",
        default=[],
        metavar="NAME",
        help="family that must be present with samples (repeatable)",
    )
    args = ap.parse_args()
    text = open(args.path, encoding="utf-8").read() if args.path else sys.stdin.read()
    try:
        check(text, args.require)
    except FormatError as e:
        print(f"check_prom: {e}", file=sys.stderr)
        return 1
    families = len({f for f in text.splitlines() if f.startswith('# TYPE ')})
    print(f"check_prom: OK ({families} families)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
