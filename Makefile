# Repo-level entry points. `make artifacts` is the one every Rust test,
# bench and doc references: it AOT-lowers the JAX/Pallas computations to
# the HLO-text artifacts the PJRT runtime executes (python/compile/aot.py).

PYTHON ?= python3

.PHONY: artifacts artifacts-full test test-python

# Default shape buckets (CI + tests). Regenerates artifacts/manifest.txt;
# the CI artifact-staleness job fails if the result differs from the
# checked-in lowering.
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

# Paper-scale buckets on top of the defaults (fig2 full-scale benches).
artifacts-full:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts --full

# Tier-1 verify (ROADMAP).
test:
	cd rust && cargo build --release && cargo test -q

test-python:
	cd python && $(PYTHON) -m pytest tests -q
